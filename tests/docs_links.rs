//! Guard for the `docs/` tree: relative markdown links must resolve,
//! the rustdoc entry points must keep pointing at the docs, and the
//! docs must keep naming the symbols they document — so the tree can't
//! rot silently when code moves. (CI also runs this via `cargo test`;
//! the workflow's docs job additionally builds rustdoc with warnings
//! denied.)

use std::fs;
use std::path::{Path, PathBuf};

fn md_files(dir: &Path) -> Vec<PathBuf> {
    let mut out: Vec<PathBuf> = fs::read_dir(dir)
        .unwrap_or_else(|e| panic!("read {dir:?}: {e}"))
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.extension().is_some_and(|x| x == "md"))
        .collect();
    out.sort();
    out
}

/// Extract every `](target)` markdown-link target in `text`.
fn link_targets(text: &str) -> Vec<String> {
    let mut out = Vec::new();
    let bytes = text.as_bytes();
    for i in 0..bytes.len().saturating_sub(1) {
        if bytes[i] == b']' && bytes[i + 1] == b'(' {
            if let Some(end) = text[i + 2..].find(')') {
                out.push(text[i + 2..i + 2 + end].to_string());
            }
        }
    }
    out
}

fn is_external(t: &str) -> bool {
    t.starts_with("http://") || t.starts_with("https://") || t.starts_with("mailto:")
}

#[test]
fn docs_markdown_links_resolve() {
    let docs = Path::new("docs");
    let files = md_files(docs);
    assert!(
        files.iter().any(|f| f.ends_with("ARCHITECTURE.md")),
        "docs/ARCHITECTURE.md is missing"
    );
    assert!(files.iter().any(|f| f.ends_with("EVALUATORS.md")), "docs/EVALUATORS.md is missing");
    for f in files {
        let text = fs::read_to_string(&f).unwrap();
        for link in link_targets(&text) {
            let target = link.split('#').next().unwrap();
            if target.is_empty() || is_external(target) {
                continue;
            }
            let resolved = f.parent().unwrap().join(target);
            assert!(
                resolved.exists(),
                "{}: broken relative link `{link}` (resolved to {resolved:?})",
                f.display()
            );
        }
    }
}

#[test]
fn rustdoc_points_at_the_docs_tree() {
    let lib = fs::read_to_string("rust/src/lib.rs").unwrap();
    for doc in ["docs/ARCHITECTURE.md", "docs/EVALUATORS.md"] {
        assert!(lib.contains(doc), "lib.rs rustdoc lost its pointer to {doc}");
    }
}

#[test]
fn docs_mention_live_symbols() {
    // Cheap rot check: the evaluator guide must reference the three
    // backends by their real type names, and the architecture tour the
    // load-bearing components of the unified accuracy+cycles path.
    let ev = fs::read_to_string("docs/EVALUATORS.md").unwrap();
    for sym in [
        "HostEval",
        "IssEval",
        "AnalyticEval",
        "PjrtEval",
        "run_model_batch",
        "divergence",
        "--shard",
        "--audit-every",
        "CostCache",
        // Every backend doubles as the rung evaluator of the guided
        // search — the guide must say so.
        "--search",
        "eval_len",
        // The backend-pinning rule extends to the result store: the
        // guide must keep saying `--store` keys embed the backend tag.
        "--store",
        "StoreKey",
        // And to the cluster axis: shards of one sweep must agree on
        // `--cores`, pinned before the store attaches.
        "--cores",
        "set_cluster",
        // The scale knobs are backend-independent — the guide must
        // keep documenting both guards.
        "--space-budget",
        "--max-alive",
    ] {
        assert!(ev.contains(sym), "docs/EVALUATORS.md no longer mentions `{sym}`");
    }
    let arch = fs::read_to_string("docs/ARCHITECTURE.md").unwrap();
    for sym in [
        "SimSession",
        "run_model_batch",
        "Coordinator",
        "AccuracyEval",
        "CompiledImage",
        // The superinstruction catalog must keep naming the engine's
        // fused op classes and their hit-counter surface.
        "Requant",
        "CountedLoop",
        "EngineStats",
        // The sharded-sweeps section must keep naming the pipeline's
        // load-bearing pieces.
        "ShardSpec",
        "ShardArtifact",
        "sweep_sharded",
        "SHARD_SCHEMA_VERSION",
        "SessionSnapshot",
        "ShardError",
        "pareto_front",
        // The execution-plan section must keep naming the lowering
        // pipeline, the cache keying and the observer contract.
        "ExecutionPlan",
        "plan_for",
        "host_logits",
        "run_plan",
        "PlanObserver",
        "StepTrace",
        "plan_compiles",
        "--trace-steps",
        "--merge-dir",
        // The analytic-fast-path section must keep naming the cost
        // cache, the execution-mode switch and the audit counters.
        "CostCache",
        "ExecMode",
        "audit_indices",
        "analytic_hits",
        "audit_mismatches",
        "--audit-every",
        // The guided-search section must keep naming the driver, its
        // knobs and the shared seeded-subsampling helper.
        "guided_search",
        "sweep_guided",
        "SearchStrategy",
        "GuidedOpts",
        "RUNG_THRESHOLD",
        "seeded_stride",
        "--search",
        "--rungs",
        "--eta",
        // The streaming-config-spaces section must keep naming the
        // lazy space, the streaming engine, the memory ledger and the
        // scale knobs.
        "ConfigSpace",
        "guided_search_stream",
        "run_sweep_space",
        "sweep_guided_space",
        "member_indices_in",
        "peak_alive",
        "--space-budget",
        "--max-alive",
        // The result-store section must keep naming the key
        // derivation, the durability policy and the daemon surface.
        "ResultStore",
        "StoreKey",
        "content_fingerprint",
        "dataset_digest",
        "store_hits",
        "quarantine",
        "STORE_SCHEMA_VERSION",
        "--store",
        "mpnn serve",
        "/eval",
        "/pareto",
        "/stats",
        // The cluster-execution section must keep naming the overlay's
        // geometry, scheduler and contention-accounting pieces.
        "ClusterConfig",
        "ClusterPerf",
        "cluster_config_total",
        "partition",
        "bank_conflict_stalls",
        "BANKING_FACTOR",
        "set_cluster",
        "--cores",
    ] {
        assert!(arch.contains(sym), "docs/ARCHITECTURE.md no longer mentions `{sym}`");
    }
    // The plan symbols the docs name must still exist in the crate.
    let plan = fs::read_to_string("rust/src/models/plan.rs").unwrap();
    for sym in [
        "pub struct ExecutionPlan",
        "pub fn plan_for",
        "pub fn compile",
        "pub fn host_logits",
        "pub trait PlanObserver",
        "pub struct StepEvent",
        "pub enum Step",
        "pub fn content_fingerprint",
    ] {
        assert!(plan.contains(sym), "models/plan.rs lost `{sym}` — update the docs");
    }
    let sim_exec = fs::read_to_string("rust/src/models/sim_exec.rs").unwrap();
    for sym in [
        "pub fn run_plan",
        "pub fn run_plan_batch",
        "pub struct StepTrace",
        "pub enum ExecMode",
        "pub fn audit_indices",
        "pub fn audit_run",
    ] {
        assert!(sim_exec.contains(sym), "models/sim_exec.rs lost `{sym}` — update the docs");
    }
    let session = fs::read_to_string("rust/src/sim/session.rs").unwrap();
    for sym in [
        "plan_compiles",
        "plan_hits",
        "pub struct CostCache",
        "pub struct CostKey",
        "analytic_hits",
        "analytic_audits",
        "audit_mismatches",
    ] {
        assert!(session.contains(sym), "sim/session.rs lost `{sym}` — update the docs");
    }
    // The shard symbols the docs name must still exist in the crate.
    let shard = fs::read_to_string("rust/src/dse/shard.rs").unwrap();
    for sym in [
        "pub struct ShardSpec",
        "pub struct ShardArtifact",
        "pub enum ShardError",
        "pub fn merge",
        "pub fn member_indices_in",
        "SHARD_SCHEMA_VERSION",
    ] {
        assert!(shard.contains(sym), "dse/shard.rs lost `{sym}` — update the docs");
    }
    // The guided-search symbols the docs name must still exist.
    let search = fs::read_to_string("rust/src/dse/search.rs").unwrap();
    for sym in [
        "pub fn guided_search",
        "pub fn guided_search_stream",
        "pub enum SearchStrategy",
        "pub struct GuidedOpts",
        "pub const RUNG_THRESHOLD",
        "pub peak_alive",
        "pub max_alive",
    ] {
        assert!(search.contains(sym), "dse/search.rs lost `{sym}` — update the docs");
    }
    // The streaming-space symbols the docs name must still exist.
    let dse = fs::read_to_string("rust/src/dse/mod.rs").unwrap();
    for sym in ["pub struct ConfigSpace", "pub fn enumerate", "pub fn get", "pub fn iter"] {
        assert!(dse.contains(sym), "dse/mod.rs lost `{sym}` — update the docs");
    }
    let rng = fs::read_to_string("rust/src/rng.rs").unwrap();
    assert!(
        rng.contains("pub fn seeded_stride"),
        "rng.rs lost `seeded_stride` — update the docs"
    );
    // The engine symbols the catalog documents must still exist.
    let engine = fs::read_to_string("rust/src/sim/engine.rs").unwrap();
    for sym in ["Requant", "CountedLoop", "pub struct EngineStats", "fusion_census"] {
        assert!(engine.contains(sym), "sim/engine.rs lost `{sym}` — update the docs catalog");
    }
    // The symbols the docs name must still exist in the crate (grep
    // over the source tree keeps this honest without a compiler).
    let coord = fs::read_to_string("rust/src/coordinator/mod.rs").unwrap();
    for sym in [
        "pub struct HostEval",
        "pub struct IssEval",
        "pub struct AnalyticEval",
        "pub struct PjrtEval",
        "pub fn sweep_guided",
        "pub fn sweep_guided_space",
        "pub fn run_sweep_space",
        "pub fn attach_store",
    ] {
        assert!(coord.contains(sym), "coordinator lost `{sym}` — update docs/EVALUATORS.md");
    }
    // The cluster-overlay symbols the docs name must still exist.
    let cluster = fs::read_to_string("rust/src/sim/cluster.rs").unwrap();
    for sym in [
        "pub struct ClusterConfig",
        "pub struct ClusterPerf",
        "pub fn partition",
        "pub fn bank_conflict_stalls",
        "pub fn split_layer",
        "pub const BANKING_FACTOR",
    ] {
        assert!(cluster.contains(sym), "sim/cluster.rs lost `{sym}` — update the docs");
    }
    // The store/serve symbols the docs name must still exist.
    let store = fs::read_to_string("rust/src/store/mod.rs").unwrap();
    for sym in [
        "pub struct ResultStore",
        "pub struct StoreKey",
        "pub enum StoreError",
        "pub fn dataset_digest",
        "STORE_SCHEMA_VERSION",
    ] {
        assert!(store.contains(sym), "store/mod.rs lost `{sym}` — update the docs");
    }
    let serve = fs::read_to_string("rust/src/serve.rs").unwrap();
    for sym in ["pub struct Server", "/eval", "/pareto", "/stats", "/shutdown"] {
        assert!(serve.contains(sym), "serve.rs lost `{sym}` — update the docs");
    }
}
