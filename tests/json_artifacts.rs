//! Round-trip guards for the JSON formats CI uploads/consumes as
//! artifacts: the sharded-sweep [`ShardArtifact`] schema and the bench
//! harness's `BENCH_<name>.json` [`JsonReport`]. Each document must
//! parse back and re-emit **byte-identically** — the property the
//! sharded merge (files cross process/host boundaries) and the perf
//! trajectory tooling (files are diffed across PRs) both lean on.

use mpnn::bench::{JsonReport, Stats};
use mpnn::dse::shard::{ShardArtifact, ShardSpec, ShardStrategy, SHARD_SCHEMA_VERSION};
use mpnn::dse::EvalPoint;
use mpnn::json::Json;
use mpnn::sim::session::SessionSnapshot;
use mpnn::sim::EngineStats;
use std::time::Duration;

fn rich_artifact() -> ShardArtifact {
    let mk = |bits: &[u32], acc: f32, cyc: u64, iss: Option<u64>, div: Option<f32>| EvalPoint {
        config: bits.to_vec(),
        accuracy: acc,
        mac_instructions: cyc / 2,
        cycles: cyc,
        mem_accesses: cyc / 3,
        iss_cycles: iss,
        divergence: div,
    };
    ShardArtifact {
        model: "mcunet_vww".to_string(),
        evaluator: "iss".to_string(),
        spec: ShardSpec::new(2, 5, ShardStrategy::Range).unwrap(),
        total_configs: 120,
        // Full-range u64: the schema stores seeds as decimal strings
        // precisely so this survives the f64-typed JSON number path.
        seed: u64::MAX,
        eval_n: 128,
        // Awkward float: not exactly representable — the emitter must
        // print a shortest round-trippable form.
        float_acc: 0.8374999,
        baseline_instrs: 987_654_321,
        search: mpnn::dse::search::SearchStrategy::Exhaustive,
        rungs: 0,
        eta: 0,
        cores: 1,
        points: vec![
            (48, mk(&[8, 4, 2, 4], 0.75, 1_000_001, Some(123_456_789), Some(0.0))),
            (49, mk(&[8, 2, 2, 2], 0.015625, 7, None, None)),
            (50, mk(&[8, 8, 8, 8], 1.0, u32::MAX as u64, Some(0), Some(0.33333334))),
        ],
        stats: SessionSnapshot {
            mem_reuses: 12,
            mem_allocs: 3,
            runs: 15,
            engine: EngineStats {
                load_mac: 1 << 40,
                scalar_mac: 2,
                latch: 3,
                requant: 4,
                counted_loops: 5,
                counted_iters: 6,
                fallbacks: 0,
            },
        },
    }
}

#[test]
fn shard_artifact_parse_reemit_is_byte_identical() {
    let art = rich_artifact();
    let text = art.to_json().to_string();
    // Schema version is embedded, so old readers can reject new files.
    assert!(text.contains(&format!("\"schema_version\":{SHARD_SCHEMA_VERSION}")));

    // Struct-level round trip: every field (floats bit-exact).
    let back = ShardArtifact::from_str(&text).unwrap();
    assert_eq!(back, art);
    assert_eq!(back.float_acc.to_bits(), art.float_acc.to_bits());
    assert_eq!(back.seed, u64::MAX);
    assert_eq!(back.points[2].1.divergence.unwrap().to_bits(), 0.33333334f32.to_bits());

    // Byte-level round trip: parse → re-emit compares equal, twice
    // (a fixed point, not merely a cycle).
    let reparsed = Json::parse(&text).unwrap().to_string();
    assert_eq!(reparsed, text);
    assert_eq!(back.to_json().to_string(), text);
}

#[test]
fn shard_artifact_field_order_is_deterministic() {
    // Two structurally identical artifacts serialise to identical
    // bytes — the property that lets CI `cmp` merged vs unsharded
    // outputs instead of doing a semantic diff.
    assert_eq!(rich_artifact().to_json().to_string(), rich_artifact().to_json().to_string());
}

#[test]
fn bench_json_report_parse_reemit_is_byte_identical() {
    let mut report = JsonReport::new("iss_throughput");
    let stats = Stats {
        name: "dense_8b/engine".to_string(),
        samples: vec![
            Duration::from_nanos(1_200_345),
            Duration::from_nanos(1_199_999),
            Duration::from_nanos(1_300_000),
        ],
    };
    report.record(&stats, &[("mips", 840.25), ("insns", 1.0e9)]);
    let stats2 = Stats { name: "conv_4b/legacy".to_string(), samples: vec![Duration::from_nanos(42)] };
    report.record(&stats2, &[]);
    report.summary("worst_speedup", 2.125);
    report.summary("engine_vs_v1", 1.5);

    let text = report.to_json().to_string();
    let parsed = Json::parse(&text).unwrap();
    // parse → re-emit → byte-compare.
    assert_eq!(parsed.to_string(), text);

    // And the fields CI tooling reads are where the schema says.
    assert_eq!(parsed.get("bench").unwrap().as_str(), Some("iss_throughput"));
    let entries = parsed.get("entries").unwrap().as_arr().unwrap();
    assert_eq!(entries.len(), 2);
    assert_eq!(entries[0].get("name").unwrap().as_str(), Some("dense_8b/engine"));
    assert_eq!(entries[0].get("iters").unwrap().as_i64(), Some(3));
    assert_eq!(entries[0].get("mips").unwrap().as_f64(), Some(840.25));
    assert_eq!(parsed.get("worst_speedup").unwrap().as_f64(), Some(2.125));
}

#[test]
fn bench_json_file_round_trips_from_disk() {
    let mut report = JsonReport::new("roundtrip_probe");
    report.record(
        &Stats { name: "probe".to_string(), samples: vec![Duration::from_nanos(5)] },
        &[("ratio", 0.125)],
    );
    let dir = std::env::temp_dir().join(format!("mpnn_bench_json_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = report.write_to(&dir).unwrap();
    assert!(path.ends_with("BENCH_roundtrip_probe.json"));
    let text = std::fs::read_to_string(&path).unwrap();
    assert_eq!(Json::parse(&text).unwrap().to_string(), text);
    assert_eq!(text, report.to_json().to_string());
    std::fs::remove_dir_all(&dir).ok();
}
