//! Execution-plan equivalence property tests.
//!
//! The plan refactor's contract is *behaviour-preserving lowering*:
//! compiling a `(QModel, modes)` pair into an `ExecutionPlan` and
//! interpreting it must be bit-identical to the pre-refactor graph
//! walks. To pin that against the actual pre-refactor behaviour, this
//! file carries **verbatim reimplementations of the legacy walkers**
//! (the old `infer::qforward` and `sim_exec::run_model` bodies, which
//! re-derived kernel specs / padding / requants on every run) built on
//! the same public layer/kernel APIs, and property-checks:
//!
//! 1. plan-driven host logits ([`qforward`]) == legacy host walk,
//!    bit-identical, and
//! 2. plan-driven ISS runs ([`run_model`]) == legacy ISS walk —
//!    logits, per-layer cycle counts and memory accesses — for both
//!    the extended (per-layer modes) and baseline executions,
//!
//! across the synthetic zoo models and seeded-random mixed-precision
//! configurations.

use mpnn::isa::MacMode;
use mpnn::kernels::conv::ConvSpec;
use mpnn::kernels::dense::DenseSpec;
use mpnn::kernels::depthwise::DwSpec;
use mpnn::kernels::run::{run_conv_with, run_dense_with, run_depthwise_with};
use mpnn::models::infer::{
    calibrate, qforward, quantize_input, quantize_model, random_params, residual_requants, QModel,
};
use mpnn::models::plan::{canonical_modes, compile, plan_for};
use mpnn::models::sim_exec::{baseline_modes, modes_for, run_model, run_plan, ExecMode};
use mpnn::models::synthetic::generate;
use mpnn::models::{zoo, LayerSpec, ModelSpec, Node};
use mpnn::nn::layers::{
    pad_spatial, qadd, qavgpool_global, qconv2d, qdense, qdepthwise, qmaxpool2, ConvGeom,
};
use mpnn::nn::tensor::{pad_channels, Tensor};
use mpnn::rng::Rng;
use mpnn::sim::{MacUnitConfig, PerfCounters};

// ------------------------------------------------ legacy host walker ---

enum Flow {
    Map(Tensor<i8>),
    Flat(Vec<i8>),
}

impl Flow {
    fn flat(self) -> Vec<i8> {
        match self {
            Flow::Map(t) => t.data,
            Flow::Flat(v) => v,
        }
    }
    fn map(self) -> Tensor<i8> {
        match self {
            Flow::Map(t) => t,
            Flow::Flat(_) => panic!("expected a feature map"),
        }
    }
}

fn legacy_run_qlayer(qm: &QModel, l: &LayerSpec, x: Flow, li: &mut usize) -> Flow {
    match *l {
        LayerSpec::Conv { cout, k, stride, pad, relu } => {
            let q = &qm.layers[*li];
            *li += 1;
            Flow::Map(qconv2d(&x.map(), &q.qw, &q.bias, cout, ConvGeom { k, stride, pad }, q.rq, relu))
        }
        LayerSpec::Depthwise { k, stride, pad, relu } => {
            let q = &qm.layers[*li];
            *li += 1;
            Flow::Map(qdepthwise(&x.map(), &q.qw, &q.bias, ConvGeom { k, stride, pad }, q.rq, relu))
        }
        LayerSpec::Dense { out, relu } => {
            let q = &qm.layers[*li];
            *li += 1;
            let flat = x.flat();
            let (qv, _) = qdense(&flat, &q.qw, &q.bias, out, Some(q.rq), relu);
            Flow::Flat(qv)
        }
        LayerSpec::MaxPool2 => Flow::Map(qmaxpool2(&x.map())),
        LayerSpec::AvgPoolGlobal => {
            let m = x.map();
            let c = m.shape[2];
            Flow::Map(Tensor::from_vec(&[1, 1, c], qavgpool_global(&m)))
        }
    }
}

/// The pre-refactor `infer::qforward`: a per-run graph walk.
fn legacy_qforward(qm: &QModel, input: &Tensor<i8>) -> Vec<i32> {
    let mut x = Flow::Map(input.clone());
    let mut li = 0usize;
    let mut res_i = 0usize;
    for node in &qm.spec.nodes {
        match node {
            Node::Layer(LayerSpec::Dense { out, .. }) if qm.analysis.layers[li].is_last => {
                let q = &qm.layers[li];
                let flat = x.flat();
                let (_, accs) = qdense(&flat, &q.qw, &q.bias, *out, None, false);
                return accs;
            }
            Node::Layer(l) => {
                x = legacy_run_qlayer(qm, l, x, &mut li);
            }
            Node::Residual(inner) => {
                let skip = x.map();
                let mut b = Flow::Map(skip.clone());
                for l in inner {
                    b = legacy_run_qlayer(qm, l, b, &mut li);
                }
                let (rq_skip, rq_branch) = residual_requants(qm, res_i);
                res_i += 1;
                x = Flow::Map(qadd(&skip, rq_skip, &b.map(), rq_branch));
            }
        }
    }
    panic!("model must end in a dense logits layer")
}

// ------------------------------------------------- legacy ISS walker ---

fn pad_conv_weights(qw: &[i8], cout: usize, k: usize, cin: usize, cin_p: usize) -> Vec<i8> {
    if cin == cin_p {
        return qw.to_vec();
    }
    let mut out = vec![0i8; cout * k * k * cin_p];
    for oc in 0..cout {
        for t in 0..k * k {
            let src = (oc * k * k + t) * cin;
            let dst = (oc * k * k + t) * cin_p;
            out[dst..dst + cin].copy_from_slice(&qw[src..src + cin]);
        }
    }
    out
}

/// The pre-refactor `sim_exec::run_model`: per-run spec derivation,
/// weight padding and packing (packing happens inside `run_*_with`).
fn legacy_run_model(
    qm: &QModel,
    input: &Tensor<i8>,
    modes: &[Option<MacMode>],
    mac: MacUnitConfig,
) -> (Vec<i32>, Vec<PerfCounters>) {
    assert_eq!(modes.len(), qm.layers.len());
    let mut perfs: Vec<PerfCounters> = Vec::new();
    let mut li = 0usize;
    let mut res_i = 0usize;

    fn run_one(
        qm: &QModel,
        modes: &[Option<MacMode>],
        mac: MacUnitConfig,
        l: &LayerSpec,
        x: Flow,
        li: &mut usize,
        perfs: &mut Vec<PerfCounters>,
    ) -> (Flow, Option<Vec<i32>>) {
        let idx = *li;
        let q = &qm.layers[idx];
        let info = &qm.analysis.layers[idx];
        let mode = modes[idx];
        match *l {
            LayerSpec::Conv { cout, k, stride, pad, relu } => {
                *li += 1;
                let xp = pad_spatial(&x.map(), pad);
                let (xp, cin_p) = if mode.is_some() && xp.shape[2] % 4 != 0 {
                    let p = pad_channels(&xp, 4, 0);
                    let c = p.shape[2];
                    (p, c)
                } else {
                    let c = xp.shape[2];
                    (xp, c)
                };
                let w = pad_conv_weights(&q.qw, cout, k, info.in_shape[2], cin_p);
                let spec = ConvSpec {
                    h: xp.shape[0],
                    w: xp.shape[1],
                    cin: cin_p,
                    cout,
                    k,
                    stride,
                    rq: q.rq,
                    relu,
                };
                let (out, perf) = run_conv_with(spec, mode, mac, &xp.data, &w, &q.bias).unwrap();
                perfs.push(perf);
                (Flow::Map(Tensor::from_vec(&[spec.ho(), spec.wo(), cout], out)), None)
            }
            LayerSpec::Depthwise { k, stride, pad, relu } => {
                *li += 1;
                let xp = pad_spatial(&x.map(), pad);
                let spec = DwSpec {
                    h: xp.shape[0],
                    w: xp.shape[1],
                    c: xp.shape[2],
                    k,
                    stride,
                    rq: q.rq,
                    relu,
                };
                let (out, perf) = run_depthwise_with(spec, mode, mac, &xp.data, &q.qw, &q.bias).unwrap();
                perfs.push(perf);
                (Flow::Map(Tensor::from_vec(&[spec.ho(), spec.wo(), spec.c], out)), None)
            }
            LayerSpec::Dense { out, relu } => {
                let is_last = info.is_last;
                *li += 1;
                let flat = x.flat();
                let spec = DenseSpec {
                    in_dim: flat.len(),
                    out_dim: out,
                    rq: q.rq,
                    relu,
                    out_i32: is_last,
                };
                let (qv, accs, perf) = run_dense_with(spec, mode, mac, &flat, &q.qw, &q.bias).unwrap();
                perfs.push(perf);
                if is_last {
                    (Flow::Flat(Vec::new()), Some(accs))
                } else {
                    (Flow::Flat(qv), None)
                }
            }
            LayerSpec::MaxPool2 => (Flow::Map(qmaxpool2(&x.map())), None),
            LayerSpec::AvgPoolGlobal => {
                let m = x.map();
                let c = m.shape[2];
                (Flow::Map(Tensor::from_vec(&[1, 1, c], qavgpool_global(&m))), None)
            }
        }
    }

    let mut x = Flow::Map(input.clone());
    for node in &qm.spec.nodes {
        match node {
            Node::Layer(l) => {
                let (nx, logits) = run_one(qm, modes, mac, l, x, &mut li, &mut perfs);
                if let Some(logits) = logits {
                    return (logits, perfs);
                }
                x = nx;
            }
            Node::Residual(inner) => {
                let skip = x.map();
                let mut b = Flow::Map(skip.clone());
                for l in inner {
                    let (nb, _) = run_one(qm, modes, mac, l, b, &mut li, &mut perfs);
                    b = nb;
                }
                let (rq_skip, rq_branch) = residual_requants(qm, res_i);
                res_i += 1;
                x = Flow::Map(qadd(&skip, rq_skip, &b.map(), rq_branch));
            }
        }
    }
    panic!("model must end in a dense logits layer")
}

// ------------------------------------------------------- the property ---

fn toy_residual_model() -> ModelSpec {
    ModelSpec {
        name: "toy",
        input: [8, 8, 3],
        num_classes: 4,
        nodes: vec![
            Node::Layer(LayerSpec::Conv { cout: 8, k: 3, stride: 1, pad: 1, relu: true }),
            Node::Layer(LayerSpec::MaxPool2),
            Node::Residual(vec![
                LayerSpec::Conv { cout: 16, k: 1, stride: 1, pad: 0, relu: true },
                LayerSpec::Depthwise { k: 3, stride: 1, pad: 1, relu: true },
                LayerSpec::Conv { cout: 8, k: 1, stride: 1, pad: 0, relu: false },
            ]),
            Node::Layer(LayerSpec::AvgPoolGlobal),
            Node::Layer(LayerSpec::Dense { out: 4, relu: false }),
        ],
    }
}

/// Depthwise + stride-2 geometry (non-trivial channel padding at the
/// first mode conv: Cin = 3).
fn toy_dw_stride_model() -> ModelSpec {
    ModelSpec {
        name: "toy_dw",
        input: [9, 9, 3],
        num_classes: 3,
        nodes: vec![
            Node::Layer(LayerSpec::Conv { cout: 6, k: 3, stride: 2, pad: 1, relu: true }),
            Node::Layer(LayerSpec::Depthwise { k: 3, stride: 2, pad: 1, relu: true }),
            Node::Layer(LayerSpec::Dense { out: 8, relu: true }),
            Node::Layer(LayerSpec::Dense { out: 3, relu: false }),
        ],
    }
}

fn random_bits(rng: &mut Rng, n: usize) -> Vec<u32> {
    (0..n).map(|_| [8u32, 4, 2][rng.below(3) as usize]).collect()
}

fn check_equivalence(spec: &ModelSpec, bits: &[u32], seed: u64) {
    let n = mpnn::models::analyze(spec).layers.len();
    assert_eq!(bits.len(), n);
    let params = random_params(spec, seed);
    let ds = generate(seed ^ 0xA5, 4, spec.input, spec.num_classes, 0.4);
    let sites = calibrate(spec, &params, &ds.images[..2]);
    let qm = quantize_model(spec, &params, &sites, bits);
    let mac = MacUnitConfig::full();

    for (mi, input_img) in ds.images[2..].iter().enumerate() {
        let input = quantize_input(&qm, input_img);

        // 1. Host: plan-driven qforward == legacy walk, bit-identical.
        let legacy_logits = legacy_qforward(&qm, &input);
        let plan_logits = qforward(&qm, &input);
        assert_eq!(plan_logits, legacy_logits, "{} bits {bits:?} input {mi}: host", spec.name);

        // 2. ISS: plan-driven run == legacy walk — logits AND per-layer
        // counters (cycles, memory accesses, instret), extended and
        // baseline executions alike.
        for modes in [modes_for(&qm), baseline_modes(&qm)] {
            let (llogits, lperfs) = legacy_run_model(&qm, &input, &modes, mac);
            let run = run_model(&qm, &input, &modes, mac).unwrap();
            assert_eq!(run.logits, llogits, "{} bits {bits:?} input {mi}: ISS logits", spec.name);
            assert_eq!(run.logits, legacy_logits, "{}: ISS vs host", spec.name);
            assert_eq!(run.layers.len(), lperfs.len());
            for (lr, lp) in run.layers.iter().zip(&lperfs) {
                assert_eq!(
                    lr.perf, *lp,
                    "{} bits {bits:?} input {mi} layer {}: perf counters",
                    spec.name, lr.layer
                );
            }
        }
    }
}

#[test]
fn plan_executors_match_legacy_walks_on_toy_residual() {
    let spec = toy_residual_model();
    let n = mpnn::models::analyze(&spec).layers.len();
    check_equivalence(&spec, &vec![8; n], 500);
    check_equivalence(&spec, &vec![2; n], 501);
    let mut rng = Rng::new(0xE0_01);
    for round in 0..2 {
        let bits = random_bits(&mut rng, n);
        check_equivalence(&spec, &bits, 510 + round);
    }
}

#[test]
fn plan_executors_match_legacy_walks_on_dw_stride_geometry() {
    let spec = toy_dw_stride_model();
    let n = mpnn::models::analyze(&spec).layers.len();
    check_equivalence(&spec, &vec![4; n], 520);
    let mut rng = Rng::new(0xE0_02);
    let bits = random_bits(&mut rng, n);
    check_equivalence(&spec, &bits, 521);
}

#[test]
fn plan_executors_match_legacy_walks_on_lenet5() {
    let spec = zoo::lenet5();
    let n = mpnn::models::analyze(&spec).layers.len();
    check_equivalence(&spec, &vec![4; n], 530);
    let mut rng = Rng::new(0xE0_03);
    let bits = random_bits(&mut rng, n);
    check_equivalence(&spec, &bits, 531);
}

#[test]
fn run_plan_replays_one_compiled_plan_per_config() {
    // Structural cache contract at the API level (process-global
    // counter exactness lives in tests/plan_cache_stats.rs, which owns
    // its process): repeated lookups of the same configuration return
    // the *same* compiled plan, different modes get different plans,
    // and a direct `compile` is interchangeable with the cached plan.
    let spec = toy_residual_model();
    let n = mpnn::models::analyze(&spec).layers.len();
    let params = random_params(&spec, 540);
    let ds = generate(541, 3, spec.input, spec.num_classes, 0.4);
    let sites = calibrate(&spec, &params, &ds.images[..2]);
    let qm = quantize_model(&spec, &params, &sites, &vec![4; n]);
    let input = quantize_input(&qm, &ds.images[2]);

    let ext = modes_for(&qm);
    let a = plan_for(&qm, &ext).unwrap();
    let b = plan_for(&qm, &ext).unwrap();
    assert!(std::sync::Arc::ptr_eq(&a, &b), "same config must replay one plan");
    let base = plan_for(&qm, &baseline_modes(&qm)).unwrap();
    assert!(!std::sync::Arc::ptr_eq(&a, &base), "modes are part of the plan key");

    // A freshly compiled (uncached) plan is behaviourally identical to
    // the cached one.
    let fresh = compile(&qm, &ext).unwrap();
    let r_cached = run_plan(&a, &input, MacUnitConfig::full(), ExecMode::Iss, None).unwrap();
    let r_fresh = run_plan(&fresh, &input, MacUnitConfig::full(), ExecMode::Iss, None).unwrap();
    assert_eq!(r_cached.logits, r_fresh.logits);
    assert_eq!(r_cached.total_cycles(), r_fresh.total_cycles());
    assert_eq!(r_cached.logits, qforward(&qm, &input), "plan ISS vs plan host");
    assert_eq!(canonical_modes(&qm), ext);
}
