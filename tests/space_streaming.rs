//! Property harness for the lazy configuration space
//! (`dse::ConfigSpace`) and the bounded-memory guided driver behind
//! it:
//!
//! (a) **bit-identity** — for randomized `(n_layers, pinned, budget,
//!     seed)` across both regimes (exhaustive mixed-radix decode,
//!     structured families + seeded random fill), streaming the space
//!     yields exactly the historical materialized enumeration, content
//!     and order — checked against an inline copy of the original
//!     O(n²)-dedup algorithm, not against `enumerate` (which now
//!     delegates to the space and would make the check circular);
//! (b) **index round-trip** — `space.get(i) == space.iter().nth(i)`
//!     for every regime;
//! (c) **shard composition** — `ShardSpec::member_indices_in` over the
//!     lazy space equals `member_indices` over the materialized slice
//!     for both partitioning strategies;
//! (d) **bounded-memory guided sweep at 10^6+ scale** — a designed
//!     3^13-configuration landscape (1,594,323 configs) runs through
//!     `guided_search_stream` end to end with the peak-materialized
//!     ledger (`GuidedStats::peak_alive`) staying O(alive + front) —
//!     asserted via the counter, not wall-clock — while the front still
//!     carries the designed optimum;
//! (e) **typed overflow** — a landscape whose alive set cannot shrink
//!     under the cap fails with the `--max-alive` error instead of
//!     materializing the space.

use mpnn::dse::search::{guided_search_stream, CostVec, GuidedOpts, RUNG_THRESHOLD};
use mpnn::dse::shard::{ShardSpec, ShardStrategy};
use mpnn::dse::{default_pinned, enumerate, Config, ConfigSpace, EvalPoint, WIDTHS};
use mpnn::error::Result;
use mpnn::rng::Rng;

// ---------------------------------------------- (a) + (b): identity ---

/// The historical `enumerate`, verbatim — mixed-radix loop for the
/// exhaustive regime, `out.contains` (O(n²)) dedup for the structured
/// one. The independent oracle the streaming space is compared against.
fn reference_enumerate(n_layers: usize, pinned: &[usize], budget: usize, seed: u64) -> Vec<Config> {
    let free: Vec<usize> = (0..n_layers).filter(|i| !pinned.contains(i)).collect();
    if let Some(total) = 3usize.checked_pow(free.len() as u32) {
        if total <= budget {
            let mut out = Vec::with_capacity(total);
            for i in 0..total {
                let mut cfg = vec![8u32; n_layers];
                let mut rest = i;
                for &l in &free {
                    cfg[l] = WIDTHS[rest % 3];
                    rest /= 3;
                }
                out.push(cfg);
            }
            return out;
        }
    }
    let mut out: Vec<Config> = Vec::new();
    for w in WIDTHS {
        let mut cfg = vec![w; n_layers];
        for &p in pinned {
            cfg[p] = 8;
        }
        if !out.contains(&cfg) {
            out.push(cfg);
        }
    }
    for split in 0..=free.len() {
        for (high, low) in [(8u32, 4u32), (8, 2), (4, 2)] {
            let mut cfg = vec![8u32; n_layers];
            for (j, &l) in free.iter().enumerate() {
                cfg[l] = if j < split { high } else { low };
            }
            for &p in pinned {
                cfg[p] = 8;
            }
            if !out.contains(&cfg) {
                out.push(cfg);
            }
        }
    }
    let mut rng = Rng::new(seed);
    while out.len() < budget {
        let mut cfg = vec![8u32; n_layers];
        for &l in &free {
            cfg[l] = WIDTHS[rng.below(3) as usize];
        }
        if !out.contains(&cfg) {
            out.push(cfg);
        }
    }
    out.truncate(budget);
    out
}

#[test]
fn streaming_matches_the_reference_enumeration_on_random_parameters() {
    for seed in 0..40u64 {
        let mut rng = Rng::new(0xA11CE ^ seed.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let n_layers = 2 + rng.below(8) as usize; // 2..=9 layers
        let pinned: Vec<usize> = match rng.below(3) {
            0 => vec![],
            1 => vec![0],
            _ => vec![0, n_layers - 1],
        };
        let free = n_layers - pinned.len();
        // Half the draws force the exhaustive regime (budget == 3^free),
        // half leave the regime to the budget roll (often structured).
        let budget = if rng.below(2) == 0 {
            3usize.pow(free as u32)
        } else {
            20 + rng.below(200) as usize
        };
        let space = ConfigSpace::new(n_layers, &pinned, budget, seed);
        let reference = reference_enumerate(n_layers, &pinned, budget, seed);
        let ctx = format!(
            "seed {seed} (layers {n_layers}, pinned {pinned:?}, budget {budget}, \
             exhaustive {})",
            space.is_exhaustive()
        );
        assert_eq!(space.len(), reference.len(), "{ctx}: cardinality");
        assert_eq!(
            space.is_exhaustive(),
            3usize.checked_pow(free as u32).is_some_and(|t| t <= budget),
            "{ctx}: regime selection"
        );
        let streamed: Vec<Config> = space.iter().collect();
        assert_eq!(streamed, reference, "{ctx}: streamed content/order drifted");
        for (i, cfg) in reference.iter().enumerate() {
            assert_eq!(&space.get(i), cfg, "{ctx}: get({i}) drifted");
        }
        // And the public materializer is the same thing.
        assert_eq!(enumerate(n_layers, &pinned, budget, seed), reference, "{ctx}: enumerate");
    }
}

#[test]
fn get_round_trips_through_the_iterator_in_both_regimes() {
    for (n_layers, budget, seed) in [(4usize, 100usize, 1u64), (28, 120, 7)] {
        let space = ConfigSpace::new(n_layers, &default_pinned(), budget, seed);
        assert!(!space.is_empty());
        for i in [0, 1, space.len() / 2, space.len() - 1] {
            assert_eq!(
                Some(space.get(i)),
                space.iter().nth(i),
                "layers {n_layers}: get({i}) != iter().nth({i})"
            );
        }
        // The iterator's length contract holds (the bounded producer
        // sizes its result table off this).
        assert_eq!(space.iter().len(), space.len());
        assert_eq!(space.iter().count(), space.len());
    }
}

// ------------------------------------------ (c): shard composition ---

#[test]
fn shard_membership_over_the_space_matches_the_materialized_slice() {
    for (n_layers, budget, seed) in [(4usize, 100usize, 1u64), (28, 120, 7)] {
        let space = ConfigSpace::new(n_layers, &default_pinned(), budget, seed);
        let configs = enumerate(n_layers, &default_pinned(), budget, seed);
        for strategy in [ShardStrategy::Hash, ShardStrategy::Range] {
            for count in 1..=5 {
                let mut union: Vec<usize> = Vec::new();
                for index in 0..count {
                    let spec = ShardSpec { index, count, strategy };
                    let streamed = spec.member_indices_in(&space);
                    assert_eq!(
                        streamed,
                        spec.member_indices(&configs),
                        "layers {n_layers}, {strategy:?} {index}/{count}: membership drifted"
                    );
                    union.extend(streamed);
                }
                union.sort_unstable();
                assert_eq!(
                    union,
                    (0..space.len()).collect::<Vec<_>>(),
                    "layers {n_layers}, {strategy:?} /{count}: shards must partition the space"
                );
            }
        }
    }
}

// ---------------------------- (d): bounded memory at 10^6+ configs ---

/// Free layers of the big designed space: 3^13 = 1,594,323
/// configurations — comfortably past the 10^6 mark while a full
/// materialization (13-word configs) would be ~160 MB of Vec traffic
/// the streamed sweep never allocates.
const BIG_FREE: u32 = 13;

/// Designed landscape over the exhaustive big space, priced by total
/// bit-sum so the all-2-bit configuration (global index `3^13 - 1`) is
/// strictly cheapest on every axis, perfectly accurate, and everything
/// else scores zero — rung 0 (prefix n/2) proves every other
/// configuration dominated, so the driver fully evaluates exactly one
/// config out of 1.59 M.
fn bit_sum(space: &ConfigSpace, i: usize) -> u64 {
    space.get(i).iter().map(|&b| b as u64).sum()
}

#[test]
fn guided_sweep_over_1_59_million_configs_stays_memory_bounded() {
    let n_layers = BIG_FREE as usize + 1; // layer 0 pinned at 8-bit
    let budget = 3usize.pow(BIG_FREE);
    let space = ConfigSpace::new(n_layers, &default_pinned(), budget, 0);
    assert!(space.is_exhaustive(), "the big space must be index-decoded");
    assert_eq!(space.len(), 1_594_323);
    let star = space.len() - 1; // all free layers at 2-bit
    assert!(space.get(star).iter().skip(1).all(|&b| b == 2));

    let n = 16usize;
    let is_star = |i: usize| i == star;
    // Pricing decodes the config from the lazy space on every call —
    // the streamed path the real coordinator takes.
    let cost_of = |i: usize| {
        let s = bit_sum(&space, i);
        CostVec { cycles: s * 10, mac: s * 7, mem: s * 13 }
    };
    let eval_partial = |idxs: &[usize], m: usize| -> Result<Vec<u32>> {
        Ok(idxs.iter().map(|&i| if is_star(i) { m as u32 } else { 0 }).collect())
    };
    let eval_full = |idxs: &[usize]| -> Result<Vec<EvalPoint>> {
        Ok(idxs
            .iter()
            .map(|&i| {
                let c = cost_of(i);
                EvalPoint {
                    config: space.get(i),
                    accuracy: if is_star(i) { 1.0 } else { 0.0 },
                    mac_instructions: c.mac,
                    cycles: c.cycles,
                    mem_accesses: c.mem,
                    iss_cycles: None,
                    divergence: None,
                }
            })
            .collect())
    };

    // rungs = 2 puts the single rung at prefix n/2, where the star's
    // banked lower bound meets every other config's upper bound — with
    // strictly lower cost, the whole rest of the space prunes at once.
    let opts = GuidedOpts { rungs: 2, eta: 2, seed: 0, max_alive: Some(64) };
    let g = guided_search_stream(space.len(), &cost_of, n, &opts, &eval_partial, &eval_full)
        .expect("big-space guided sweep");

    assert_eq!(g.stats.space, space.len());
    assert!(!g.stats.degenerate);
    // The bounded-memory contract, asserted via the ledger: the driver
    // materialized exactly the configs it fully evaluated — never the
    // space.
    assert_eq!(g.stats.full_evals, 1, "designed landscape needs exactly one full eval");
    assert_eq!(g.stats.peak_alive, g.stats.full_evals, "peak ledger != materialized configs");
    assert!(
        g.stats.peak_alive <= 64,
        "peak alive {} blew the designed O(alive + front) bound",
        g.stats.peak_alive
    );
    assert_eq!(g.stats.pruned, space.len() - 1, "everything but the star must prune");
    assert_eq!(g.stats.repaired, 0, "the measured star proves every drop dominated");
    assert_eq!(g.stats.partial_evals, space.len(), "one rung over the whole space");
    // And the answer is right: the single surviving point is the star.
    assert_eq!(g.points.len(), 1);
    assert_eq!(g.points[0].0, star);
    assert_eq!(g.points[0].1.accuracy.to_bits(), 1.0f32.to_bits());
}

// -------------------------------------------- (e): typed overflow ---

#[test]
fn flat_landscapes_overflow_the_alive_cap_with_a_typed_error() {
    // Every config identical on every axis and every input: nothing can
    // prune (exact ties are never pruned) and promotion can only halve,
    // so the surviving alive set after the rungs is ~space/4 — far over
    // the cap, which must fail with the flag-naming error instead of
    // materializing the survivors.
    let space = 3usize.pow(8); // 6561
    assert!(space >= RUNG_THRESHOLD);
    let n = 16usize;
    let cost_of = |_i: usize| CostVec { cycles: 100, mac: 100, mem: 100 };
    let eval_partial = |idxs: &[usize], _m: usize| -> Result<Vec<u32>> {
        Ok(vec![0; idxs.len()])
    };
    let eval_full = |_idxs: &[usize]| -> Result<Vec<EvalPoint>> {
        panic!("the alive cap must trip before any full evaluation")
    };
    let opts = GuidedOpts { rungs: 3, eta: 2, seed: 9, max_alive: Some(32) };
    let err = guided_search_stream(space, &cost_of, n, &opts, &eval_partial, &eval_full)
        .expect_err("a flat landscape cannot fit a 32-config alive cap");
    assert!(err.to_string().contains("--max-alive"), "untyped overflow error: {err}");
}
