//! End-to-end integration: the AOT JAX/Pallas artifacts executed via
//! PJRT must agree **bit-exactly** with the Rust host reference (which
//! in turn is bit-exact vs the ISS kernels — tested in the lib). This
//! closes the L1(Pallas) == L2(JAX) == L3(Rust/ISS) loop.
//!
//! These tests are skipped gracefully when `make artifacts` has not run.

use mpnn::models::format::load_or_fallback;
use mpnn::models::infer::{qforward, quantize_input, quantize_model};
use mpnn::runtime::{default_artifacts_dir, run_qfwd, Session};

fn artifacts_ready(name: &str) -> bool {
    let root = default_artifacts_dir();
    root.join(format!("{name}_qfwd_b64.hlo.txt")).exists()
        && root.join("weights").join(format!("{name}.mpw")).exists()
}

fn check_model(name: &str, bits_pattern: &[u32]) {
    if !artifacts_ready(name) {
        eprintln!("skipping {name}: artifacts not built");
        return;
    }
    let root = default_artifacts_dir();
    let model = load_or_fallback(&root, name, 0).unwrap();
    let analysis = mpnn::models::analyze(&model.spec);
    let bits: Vec<u32> =
        (0..analysis.layers.len()).map(|i| bits_pattern[i % bits_pattern.len()]).collect();
    let mut bits = bits;
    bits[0] = 8; // pinned first layer, as the DSE does
    let qm = quantize_model(&model.spec, &model.params, &model.sites, &bits);

    // Host-reference logits for the first 64 test images.
    let b = 64usize;
    let px = model.spec.input.iter().product::<usize>();
    let mut images = vec![0i8; b * px];
    let mut want_logits = Vec::new();
    for j in 0..b {
        let qi = quantize_input(&qm, &model.test.images[j]);
        images[j * px..(j + 1) * px].copy_from_slice(&qi.data);
        want_logits.extend(qforward(&qm, &qi));
    }

    // PJRT execution of the same batch.
    let mut session = Session::open(&root).unwrap();
    let exe = session.load(&format!("{name}_qfwd_b64")).unwrap();
    let out = run_qfwd(exe, &qm, &images, b).unwrap();

    assert_eq!(out.logits.len(), want_logits.len());
    assert_eq!(out.logits, want_logits, "{name}: PJRT logits != host reference");
    // Predictions consistent with logits.
    for j in 0..b {
        let row = &out.logits[j * qm.spec.num_classes..(j + 1) * qm.spec.num_classes];
        let am = mpnn::models::infer::argmax_i32(row);
        assert_eq!(out.preds[j] as usize, am, "{name}: pred/logits mismatch at {j}");
    }
}

#[test]
fn lenet5_pjrt_bit_exact_mixed_widths() {
    check_model("lenet5", &[8, 4, 2]);
}

#[test]
fn cifar_cnn_pjrt_bit_exact_all4() {
    check_model("cifar_cnn", &[4]);
}

#[test]
fn mcunet_pjrt_bit_exact_residuals() {
    check_model("mcunet_vww", &[8, 4]);
}

#[test]
fn mobilenet_pjrt_bit_exact() {
    check_model("mobilenet_v1", &[4, 2]);
}

#[test]
fn standalone_kernel_artifacts_execute() {
    let root = default_artifacts_dir();
    if !root.join("kernel_packed_gemm_8b.hlo.txt").exists() {
        eprintln!("skipping: kernel artifacts not built");
        return;
    }
    use mpnn::isa::custom::pack_weight_stream;
    use mpnn::isa::MacMode;
    use mpnn::runtime::{execute, lit_i32, lit_i8, lit_u32};
    let mut session = Session::open(&root).unwrap();
    let mut rng = mpnn::rng::Rng::new(5);
    // Reference shape from aot.py: M=64, I=256, O=32.
    let (m, i, o) = (64usize, 256usize, 32usize);
    for (stem, mode) in [
        ("kernel_packed_gemm_8b", MacMode::W8),
        ("kernel_packed_gemm_4b", MacMode::W4),
        ("kernel_packed_gemm_2b", MacMode::W2),
    ] {
        let acts: Vec<i8> = (0..m * i).map(|_| rng.i8()).collect();
        let w: Vec<i8> = (0..o * i).map(|_| rng.int_bits(mode.weight_bits())).collect();
        let bias: Vec<i32> = (0..o).map(|_| rng.range_i32(-500, 500)).collect();
        let mut packed = Vec::new();
        for row in w.chunks(i) {
            packed.extend(pack_weight_stream(mode, row));
        }
        let rq = mpnn::nn::quant::Requant::from_real_scale(0.002);
        let exe = session.load(stem).unwrap();
        let args = vec![
            lit_i8(&[m, i], &acts).unwrap(),
            lit_u32(&[o, packed.len() / o], &packed).unwrap(),
            lit_i32(&[o], &bias).unwrap(),
            lit_i32(&[], &[rq.m]).unwrap(),
            lit_i32(&[], &[rq.shift as i32]).unwrap(),
        ];
        let outs = execute(exe, &args).unwrap();
        let got = outs[0].to_vec::<i8>().unwrap();
        // Host reference: plain integer GEMM + requantize (relu=true).
        for oi in 0..o {
            for mi in 0..m {
                let mut acc = bias[oi];
                for k in 0..i {
                    acc += acts[mi * i + k] as i32 * w[oi * i + k] as i32;
                }
                let want = mpnn::nn::quant::requantize(acc, rq, true);
                assert_eq!(got[mi * o + oi], want, "{stem} at ({mi},{oi})");
            }
        }
    }
}
