//! Property tests for the sharded-sweep subsystem (`dse::shard`):
//!
//! (a) partitions are disjoint and cover the space for every strategy
//!     and shard count;
//! (b) merge(shard sweeps) is **bit-identical** to the single-instance
//!     sweep — same point order, same `EvalPoint` fields (floats
//!     bit-compared, `iss_cycles`/`divergence` included), same Pareto
//!     indices, same summed session/engine stats — for shard counts
//!     {1, 2, 3, 5, 8} on the synthetic-zoo fallback model, across
//!     *separate coordinator instances* (the cross-process claim) and
//!     through a full JSON round-trip of every shard artifact;
//! (c) merging is order- and duplicate-insensitive;
//! (d) corrupted / version-mismatched artifacts fail with typed
//!     [`ShardError`]s, never a panic.

use mpnn::coordinator::{Coordinator, HostEval, IssEval};
use mpnn::dse::pareto::pareto_front;
use mpnn::dse::search::SearchStrategy;
use mpnn::dse::shard::{
    config_hash, merge, point_divergence, ShardArtifact, ShardError, ShardSpec, ShardStrategy,
};
use mpnn::dse::{default_pinned, enumerate, Config, EvalPoint};
use mpnn::exp::{EvalBackend, ExpOpts};
use mpnn::models::format::load_or_fallback;
use mpnn::rng::Rng;
use mpnn::sim::session::SessionSnapshot;
use std::path::Path;

const SHARD_COUNTS: [usize; 5] = [1, 2, 3, 5, 8];

fn host_coordinator(seed: u64) -> Coordinator {
    let model = load_or_fallback(Path::new("/nonexistent"), "lenet5", seed).unwrap();
    let test = model.test.clone();
    Coordinator::new(model, Box::new(HostEval { test }), 2).unwrap()
}

/// Build one shard's artifact the way `fig6::sweep_shard` does, but on
/// a caller-supplied coordinator (so the matrix of shard counts can
/// reuse one instance without rebuilding the cycle model every time).
fn shard_artifact(
    c: &Coordinator,
    configs: &[Config],
    spec: ShardSpec,
    seed: u64,
    eval_n: usize,
) -> ShardArtifact {
    let points = c.sweep_sharded(configs, eval_n, &spec).unwrap();
    ShardArtifact {
        model: c.model.spec.name.to_string(),
        evaluator: c.evaluator_name().to_string(),
        spec,
        total_configs: configs.len(),
        seed,
        eval_n,
        float_acc: c.model.float_acc,
        baseline_instrs: 1234, // sweep identity only; constant across shards
        search: SearchStrategy::Exhaustive,
        rungs: 0,
        eta: 0,
        cores: 1,
        points,
        stats: SessionSnapshot::default(),
    }
}

fn assert_points_bit_identical(a: &[EvalPoint], b: &[EvalPoint], ctx: &str) {
    assert_eq!(a.len(), b.len(), "{ctx}: point count");
    for (i, (pa, pb)) in a.iter().zip(b).enumerate() {
        if let Some((field, va, vb)) = point_divergence(pa, pb) {
            panic!("{ctx}: point {i} differs on `{field}`: {va} vs {vb}");
        }
    }
}

// ----------------------------------------------------- (a) partitions ---

#[test]
fn partitions_are_disjoint_and_cover_random_spaces() {
    let mut rng = Rng::new(0x5AAD);
    for round in 0..12 {
        // Random config space: either a real enumeration or raw random
        // configs (the partitioner must not rely on enumeration shape).
        let configs: Vec<Config> = if round % 2 == 0 {
            let layers = 2 + rng.below(6) as usize;
            let budget = 1 + rng.below(60) as usize;
            enumerate(layers, &default_pinned(), budget, rng.next_u64())
        } else {
            let layers = 1 + rng.below(8) as usize;
            (0..1 + rng.below(80))
                .map(|_| (0..layers).map(|_| [2u32, 4, 8][rng.below(3) as usize]).collect())
                .collect()
        };
        for strategy in [ShardStrategy::Hash, ShardStrategy::Range] {
            for count in 1..=8usize {
                let mut owners = vec![0u32; configs.len()];
                for index in 0..count {
                    let spec = ShardSpec::new(index, count, strategy).unwrap();
                    let members = spec.member_indices(&configs);
                    // Deterministic: same spec, same space, same answer.
                    assert_eq!(members, spec.member_indices(&configs));
                    // Members come back in enumeration order.
                    assert!(members.windows(2).all(|w| w[0] < w[1]));
                    for i in members {
                        owners[i] += 1;
                    }
                }
                assert!(
                    owners.iter().all(|&c| c == 1),
                    "round {round} {strategy:?} x{count}: every config must have exactly \
                     one owner, got {owners:?}"
                );
            }
        }
    }
}

#[test]
fn hash_assignment_is_stable_across_shard_counts() {
    // A config's hash — hence its residue class — never depends on the
    // shard count or its position, so growing the fleet re-partitions
    // without reshuffling identities.
    let mut rng = Rng::new(7);
    for _ in 0..100 {
        let cfg: Config = (0..1 + rng.below(10)).map(|_| [2u32, 4, 8][rng.below(3) as usize]).collect();
        let h = config_hash(&cfg);
        assert_eq!(h, config_hash(&cfg.clone()));
        for count in 1..=8usize {
            let owner: Vec<usize> = (0..count)
                .filter(|&i| {
                    ShardSpec::new(i, count, ShardStrategy::Hash).unwrap().owns(0, &cfg, 1)
                })
                .collect();
            assert_eq!(owner, vec![h as usize % count]);
        }
    }
}

// ------------------------------------------- (b) bit-identical merges ---

#[test]
fn merged_shard_sweeps_equal_single_sweep_bit_for_bit() {
    let seed = 11;
    let eval_n = 16;
    // Reference: one full sweep on its own coordinator instance.
    let single = host_coordinator(seed);
    let n = single.analysis.layers.len();
    let configs = enumerate(n, &default_pinned(), 27, seed);
    let single_points = single.run_sweep(&configs, eval_n).unwrap();
    let single_front = pareto_front(&single_points, |p| p.mac_instructions);

    // Shard side: a *different* coordinator instance stands in for the
    // remote processes (its evaluation cache makes the matrix cheap;
    // determinism across instances is exactly the property under test).
    let remote = host_coordinator(seed);
    for strategy in [ShardStrategy::Hash, ShardStrategy::Range] {
        for count in SHARD_COUNTS {
            let arts: Vec<ShardArtifact> = (0..count)
                .map(|i| {
                    let spec = ShardSpec::new(i, count, strategy).unwrap();
                    let art = shard_artifact(&remote, &configs, spec, seed, eval_n);
                    // Every artifact crosses a process boundary in
                    // production: round-trip it through its JSON schema.
                    ShardArtifact::from_str(&art.to_json().to_string()).unwrap()
                })
                .collect();
            let ctx = format!("{strategy:?} x{count}");
            // No shard evaluated more than its slice.
            let evaluated: usize = arts.iter().map(|a| a.points.len()).sum();
            assert_eq!(evaluated, configs.len(), "{ctx}: partition sizes");

            let m = merge(&arts).unwrap();
            assert_points_bit_identical(&m.points, &single_points, &ctx);
            assert_eq!(m.front, single_front, "{ctx}: Pareto indices");
            assert_eq!(m.shards, count, "{ctx}");
            assert_eq!(m.duplicate_points, 0, "{ctx}");
            assert_eq!(m.float_acc.to_bits(), single.model.float_acc.to_bits(), "{ctx}");
        }
    }
}

#[test]
fn merged_stats_are_the_sum_of_shard_stats() {
    // Synthetic per-shard stats: the merger must add them elementwise
    // (and only once per distinct artifact — see the duplicate test).
    let single = host_coordinator(13);
    let n = single.analysis.layers.len();
    let configs = enumerate(n, &default_pinned(), 27, 13);
    let mut arts: Vec<ShardArtifact> = (0..3)
        .map(|i| {
            let spec = ShardSpec::new(i, 3, ShardStrategy::Range).unwrap();
            shard_artifact(&single, &configs, spec, 13, 8)
        })
        .collect();
    let mut expected = SessionSnapshot::default();
    for (i, a) in arts.iter_mut().enumerate() {
        a.stats.mem_reuses = 10 * (i as u64 + 1);
        a.stats.mem_allocs = i as u64;
        a.stats.runs = 100 + i as u64;
        a.stats.engine.requant = 7 * i as u64;
        a.stats.engine.counted_iters = 1000 * i as u64;
        expected.add(&a.stats);
    }
    let m = merge(&arts).unwrap();
    assert_eq!(m.stats, expected);
}

#[test]
fn iss_evaluated_points_survive_sharding_with_cycles_and_divergence() {
    // The ISS backend populates `iss_cycles`/`divergence`; both must
    // survive the artifact round-trip and merge bit-for-bit.
    let model = load_or_fallback(Path::new("/nonexistent"), "lenet5", 9).unwrap();
    let test = model.test.clone();
    let c = Coordinator::new(model, Box::new(IssEval::new(test, 2)), 2).unwrap();
    let n = c.analysis.layers.len();
    let configs: Vec<Config> = vec![vec![8; n], vec![4; n], vec![2; n]];
    let single = c.run_sweep(&configs, 3).unwrap();
    assert!(single.iter().all(|p| p.iss_cycles.is_some() && p.divergence.is_some()));

    let arts: Vec<ShardArtifact> = (0..2)
        .map(|i| {
            let spec = ShardSpec::new(i, 2, ShardStrategy::Hash).unwrap();
            let art = shard_artifact(&c, &configs, spec, 9, 3);
            ShardArtifact::from_str(&art.to_json().to_string()).unwrap()
        })
        .collect();
    let m = merge(&arts).unwrap();
    assert_points_bit_identical(&m.points, &single, "iss 2-shard");
}

#[test]
fn production_shard_runner_matches_sweep_model() {
    // The fig6 entry points end to end: `sweep_shard` per shard (fresh
    // coordinator each, as separate processes would) and
    // `sweep_from_artifacts` to recombine — against `sweep_model`.
    let opts = ExpOpts {
        artifacts: "/nonexistent".into(),
        eval_n: 8,
        budget: 27,
        backend: EvalBackend::Host,
        seed: 17,
        ..ExpOpts::default()
    };
    let direct = mpnn::exp::fig6::sweep_model(&opts, "lenet5").unwrap();
    let arts: Vec<ShardArtifact> = (0..2)
        .map(|i| {
            let spec = ShardSpec::new(i, 2, ShardStrategy::Hash).unwrap();
            mpnn::exp::fig6::sweep_shard(&opts, "lenet5", &spec).unwrap()
        })
        .collect();
    let merged = mpnn::exp::fig6::sweep_from_artifacts(&opts, &arts).unwrap();
    assert_points_bit_identical(&merged.points, &direct.points, "fig6 path");
    assert_eq!(merged.front, direct.front);
    assert_eq!(merged.evaluator, direct.evaluator);
    assert_eq!(merged.float_acc.to_bits(), direct.float_acc.to_bits());
    assert_eq!(merged.baseline_instrs, direct.baseline_instrs);

    // Mistagged artifact: swap two points' global indices. Coverage
    // and conflict checks can't see it (indices stay distinct and in
    // range), so the enumeration cross-check must refuse the merge.
    let mut tampered = arts.clone();
    {
        let pts = &mut tampered[0].points;
        assert!(pts.len() >= 2, "shard 0 needs two points to swap");
        let tmp = pts[0].0;
        pts[0].0 = pts[1].0;
        pts[1].0 = tmp;
    }
    let err = mpnn::exp::fig6::sweep_from_artifacts(&opts, &tampered).unwrap_err();
    assert!(format!("{err}").contains("mistagged"), "{err}");

    // Wrong --budget at merge time: refused with guidance, not merged
    // against a different enumeration.
    let wrong_budget = ExpOpts { budget: 9, ..opts.clone() };
    let err = mpnn::exp::fig6::sweep_from_artifacts(&wrong_budget, &arts).unwrap_err();
    assert!(format!("{err}").contains("--budget"), "{err}");
}

// ------------------------------- (c) order/duplicate insensitivity ---

#[test]
fn merge_is_order_and_duplicate_insensitive() {
    let c = host_coordinator(19);
    let n = c.analysis.layers.len();
    let configs = enumerate(n, &default_pinned(), 27, 19);
    let arts: Vec<ShardArtifact> = (0..5)
        .map(|i| {
            let spec = ShardSpec::new(i, 5, ShardStrategy::Hash).unwrap();
            shard_artifact(&c, &configs, spec, 19, 8)
        })
        .collect();
    let canonical = merge(&arts).unwrap();

    let mut rng = Rng::new(23);
    for round in 0..6 {
        let mut jumbled = arts.clone();
        rng.shuffle(&mut jumbled);
        // Duplicate a random prefix (same files merged twice).
        let dup = 1 + rng.below(arts.len() as u64 - 1) as usize;
        let extra: Vec<ShardArtifact> = jumbled[..dup].to_vec();
        jumbled.extend(extra);
        let m = merge(&jumbled).unwrap();
        assert_points_bit_identical(&m.points, &canonical.points, &format!("round {round}"));
        assert_eq!(m.front, canonical.front, "round {round}");
        assert_eq!(m.stats, canonical.stats, "round {round}: duplicate stats must collapse");
        assert_eq!(m.shards, canonical.shards, "round {round}");
    }

    // Overlapping strategies: hash shards + the full 1-way sweep cover
    // every config twice with identical values — merge dedups, flags
    // the duplicates and still matches.
    let mut overlapping = arts.clone();
    overlapping.push(shard_artifact(&c, &configs, ShardSpec::whole(), 19, 8));
    let m = merge(&overlapping).unwrap();
    assert_points_bit_identical(&m.points, &canonical.points, "overlapping strategies");
    assert_eq!(m.duplicate_points, configs.len());
}

// ----------------------------------------------- (d) typed failures ---

#[test]
fn corrupted_and_mismatched_artifacts_fail_typed_not_panic() {
    let c = host_coordinator(29);
    let n = c.analysis.layers.len();
    let configs = enumerate(n, &default_pinned(), 9, 29);
    let spec = ShardSpec::whole();
    let art = shard_artifact(&c, &configs, spec, 29, 4);
    let text = art.to_json().to_string();

    // Version bump.
    let bumped = text.replace("\"schema_version\":1", "\"schema_version\":2");
    match ShardArtifact::from_str(&bumped) {
        Err(ShardError::SchemaVersion { found: 2, expected: 1 }) => {}
        other => panic!("expected SchemaVersion, got {other:?}"),
    }

    // Truncations at many offsets: typed parse/schema errors only.
    for cut in [1, text.len() / 4, text.len() / 2, text.len() - 2] {
        match ShardArtifact::from_str(&text[..cut]) {
            Err(ShardError::Parse(_)) | Err(ShardError::Schema(_)) => {}
            other => panic!("truncate@{cut}: expected typed error, got {other:?}"),
        }
    }

    // Field-level corruption.
    let negative = text.replace("\"eval_n\":4", "\"eval_n\":-4");
    match ShardArtifact::from_str(&negative) {
        Err(ShardError::Schema(e)) => assert_eq!(e.field, "eval_n"),
        other => panic!("expected Schema(eval_n), got {other:?}"),
    }
    let bad_strategy = text.replace("\"strategy\":\"hash\"", "\"strategy\":\"roulette\"");
    match ShardArtifact::from_str(&bad_strategy) {
        Err(ShardError::Schema(e)) => assert_eq!(e.field, "strategy"),
        other => panic!("expected Schema(strategy), got {other:?}"),
    }

    // File-level: a corrupted file loads as Err (never a panic) and the
    // message keeps the path context.
    let dir = std::env::temp_dir().join(format!("mpnn_shard_test_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("corrupt.json");
    std::fs::write(&path, &text[..text.len() / 3]).unwrap();
    let err = ShardArtifact::load(&path).unwrap_err();
    assert!(format!("{err:?}").contains("corrupt.json"), "{err:?}");
    std::fs::remove_dir_all(&dir).ok();

    // Conflicting shards: same config, different accuracy.
    let s0 = ShardSpec::new(0, 2, ShardStrategy::Range).unwrap();
    let s1 = ShardSpec::new(1, 2, ShardStrategy::Range).unwrap();
    let a0 = shard_artifact(&c, &configs, s0, 29, 4);
    let mut a1 = shard_artifact(&c, &configs, s1, 29, 4);
    let mut evil = a0.clone();
    evil.spec = ShardSpec::new(0, 2, ShardStrategy::Hash).unwrap();
    evil.points[0].1.accuracy += 0.125;
    match merge(&[a0.clone(), a1.clone(), evil]) {
        Err(ShardError::Conflict { field: "accuracy", .. }) => {}
        other => panic!("expected Conflict, got {other:?}"),
    }

    // Incompatible sweep identity.
    a1.seed = 31;
    match merge(&[a0.clone(), a1]) {
        Err(ShardError::Incompatible { field: "seed", .. }) => {}
        other => panic!("expected Incompatible(seed), got {other:?}"),
    }

    // Coverage gap (one shard of two) names the first missing config.
    match merge(&[a0]) {
        Err(ShardError::Coverage { first_missing: Some(_), .. }) => {}
        other => panic!("expected Coverage, got {other:?}"),
    }

    // Empty input.
    assert!(matches!(merge(&[]), Err(ShardError::Empty)));
}

// --------------------------------------------- (e) resumable shards ---

#[test]
fn shard_resume_skips_present_indices_and_reproduces_the_full_artifact() {
    let opts = ExpOpts {
        artifacts: "/nonexistent".into(),
        eval_n: 8,
        budget: 27,
        backend: EvalBackend::Host,
        seed: 37,
        ..ExpOpts::default()
    };
    let spec = ShardSpec::new(0, 2, ShardStrategy::Hash).unwrap();
    let full = mpnn::exp::fig6::sweep_shard(&opts, "lenet5", &spec).unwrap();
    assert!(full.points.len() >= 2, "need a splittable shard for this test");

    // A killed run left only the first half of the shard's points: the
    // resume must evaluate exactly the missing tail and reproduce the
    // full artifact's points bit-for-bit (evaluation is deterministic).
    let mut partial = full.clone();
    partial.points.truncate(full.points.len() / 2);
    let resumed =
        mpnn::exp::fig6::sweep_shard_resume(&opts, "lenet5", &spec, Some(&partial), None).unwrap();
    let rp: Vec<EvalPoint> = resumed.points.iter().map(|(_, p)| p.clone()).collect();
    let fp: Vec<EvalPoint> = full.points.iter().map(|(_, p)| p.clone()).collect();
    assert_eq!(
        resumed.points.iter().map(|(i, _)| *i).collect::<Vec<_>>(),
        full.points.iter().map(|(i, _)| *i).collect::<Vec<_>>(),
        "resume restores enumeration order"
    );
    assert_points_bit_identical(&rp, &fp, "resumed vs fresh shard");

    // Resuming an already-complete artifact evaluates nothing: points
    // and stats are unchanged (the host sweep adds a zero session
    // delta), so the rewritten file is byte-identical.
    let noop = mpnn::exp::fig6::sweep_shard_resume(&opts, "lenet5", &spec, Some(&full), None).unwrap();
    assert_eq!(noop, full, "complete artifact must resume to itself");
    assert_eq!(noop.to_json().to_string(), full.to_json().to_string());

    // Checkpointed run: with a checkpoint path the artifact is
    // rewritten after every SHARD_CHECKPOINT_EVERY-config chunk, so a
    // kill at any point leaves a cleanly-parsing partial artifact the
    // next run resumes from. Same process + host evaluator = zero
    // session deltas, so here the final file is fully byte-identical;
    // in general only the points payload is (a cross-process ISS
    // resume records different pool-warmth stats).
    let dir = std::env::temp_dir().join(format!("mpnn_resume_test_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let ckpt = dir.join("lenet5.s0of2.json");
    let checkpointed =
        mpnn::exp::fig6::sweep_shard_resume(&opts, "lenet5", &spec, None, Some(&ckpt)).unwrap();
    assert_eq!(checkpointed, full, "checkpointing must not change the result");
    let on_disk = ShardArtifact::load(&ckpt).unwrap();
    assert_eq!(on_disk, full, "last checkpoint write is the complete artifact");
    std::fs::remove_dir_all(&dir).ok();

    // And the resumed artifact still merges into the exact full sweep.
    let other = ShardSpec::new(1, 2, ShardStrategy::Hash).unwrap();
    let art1 = mpnn::exp::fig6::sweep_shard(&opts, "lenet5", &other).unwrap();
    let merged = merge(&[resumed, art1]).unwrap();
    let direct = mpnn::exp::fig6::sweep_model(&opts, "lenet5").unwrap();
    assert_points_bit_identical(&merged.points, &direct.points, "merged-after-resume");
    assert_eq!(merged.front, direct.front);

    // A prior artifact from a *different* sweep is refused, not mixed.
    let mut stale = full.clone();
    stale.seed = 38;
    let err =
        mpnn::exp::fig6::sweep_shard_resume(&opts, "lenet5", &spec, Some(&stale), None).unwrap_err();
    assert!(format!("{err}").contains("different sweep"), "{err}");
    // A mistagged point (wrong config at an index) is caught too.
    let mut evil = full.clone();
    evil.points[0].1.config[1] = 33;
    let err =
        mpnn::exp::fig6::sweep_shard_resume(&opts, "lenet5", &spec, Some(&evil), None).unwrap_err();
    assert!(format!("{err}").contains("mistagged"), "{err}");
}
