//! Property harness for the guided DSE driver (`dse::search`), with the
//! exhaustive sweep as the oracle:
//!
//! (a) **zero regret** — over ≥ 50 randomized synthetic landscapes the
//!     guided front equals the exhaustive Pareto front on every cost
//!     axis (same indices, same point values), which subsumes the
//!     guided-front ⊆ exhaustive-front containment with zero measured
//!     regret;
//! (b) **lower-bound soundness** — no true Pareto point is ever pruned:
//!     every exhaustive front member is fully evaluated by the guided
//!     run;
//! (c) **determinism** — two guided runs under one seed are
//!     byte-identical;
//! (d) **rung accounting** — the evaluation ledger balances, and on
//!     designed landscapes (a cheapest config that is also the most
//!     accurate) the guided run performs strictly fewer full
//!     evaluations than the exhaustive sweep;
//! (e) the same holds end to end through `Coordinator::sweep_guided`
//!     with a real `AccuracyEval` backend;
//! (f) the streamed `ConfigSpace` paths (`run_sweep_space`,
//!     `sweep_guided_space`) reproduce the materialized slice paths
//!     byte-for-byte, so the lazily decoded guided front *is* the
//!     materialized exhaustive front.
//!
//! Every randomized assertion message carries the generating seed so a
//! failure reproduces directly.

use mpnn::coordinator::{AccuracyEval, Coordinator, EvalReport, HostEval};
use mpnn::dse::pareto::pareto_front;
use mpnn::dse::search::{guided_search, CostVec, GuidedOpts, GuidedSweep, RUNG_THRESHOLD};
use mpnn::dse::{default_pinned, enumerate, total_mac_instructions, ConfigSpace, EvalPoint};
use mpnn::error::Result;
use mpnn::models::format::load_or_fallback;
use mpnn::models::infer::QModel;
use mpnn::rng::Rng;
use std::path::Path;

// ------------------------------------------------ synthetic landscapes ---

/// Analytic costs plus a per-(config, input) correctness table — the
/// closed-form stand-in for an accuracy backend, where evaluating a
/// prefix of the input set is exactly a row prefix of the table.
struct Landscape {
    costs: Vec<CostVec>,
    n: usize,
    correct: Vec<Vec<bool>>,
}

impl Landscape {
    fn point(&self, i: usize) -> EvalPoint {
        let hits = self.correct[i].iter().filter(|&&b| b).count();
        EvalPoint {
            config: vec![i as u32],
            accuracy: hits as f32 / self.n as f32,
            mac_instructions: self.costs[i].mac,
            cycles: self.costs[i].cycles,
            mem_accesses: self.costs[i].mem,
            iss_cycles: None,
            divergence: None,
        }
    }

    /// The oracle: every configuration fully evaluated.
    fn exhaustive(&self) -> Vec<EvalPoint> {
        (0..self.costs.len()).map(|i| self.point(i)).collect()
    }

    fn random(seed: u64, space: usize, n: usize) -> Landscape {
        let mut rng = Rng::new(seed);
        let costs = (0..space)
            .map(|_| CostVec {
                cycles: rng.below(40) * 10,
                mac: rng.below(40) * 10,
                mem: rng.below(40) * 10,
            })
            .collect();
        let correct = (0..space)
            .map(|_| {
                let p = rng.below(100);
                (0..n).map(|_| rng.below(100) < p).collect()
            })
            .collect();
        Landscape { costs, n, correct }
    }

    fn run(&self, opts: &GuidedOpts) -> GuidedSweep {
        let ep = |idxs: &[usize], m: usize| -> Result<Vec<u32>> {
            Ok(idxs
                .iter()
                .map(|&i| self.correct[i][..m].iter().filter(|&&b| b).count() as u32)
                .collect())
        };
        let ef = |idxs: &[usize]| -> Result<Vec<EvalPoint>> {
            Ok(idxs.iter().map(|&i| self.point(i)).collect())
        };
        guided_search(&self.costs, self.n, opts, &ep, &ef).expect("guided search")
    }
}

const AXES: [fn(&EvalPoint) -> u64; 3] =
    [|p| p.cycles, |p| p.mac_instructions, |p| p.mem_accesses];

/// (a) + (b): the guided front equals the exhaustive front on every
/// cost axis, and every true Pareto point was fully evaluated.
fn assert_oracle_agreement(land: &Landscape, g: &GuidedSweep, ctx: &str) {
    let all = land.exhaustive();
    let gpts: Vec<EvalPoint> = g.points.iter().map(|(_, p)| p.clone()).collect();
    for (ax, axis) in AXES.iter().enumerate() {
        let oracle: Vec<usize> = pareto_front(&all, axis);
        // Lower-bound soundness first: a pruned true Pareto point
        // would make the front comparison fail anyway, but this names
        // the actual violation.
        for &i in &oracle {
            let found = g.points.iter().find(|(gi, _)| *gi == i);
            let (_, gp) = found.unwrap_or_else(|| {
                panic!("{ctx}: pruning removed a true Pareto point (index {i}, axis {ax})")
            });
            assert_eq!(*gp, all[i], "{ctx}: fully-evaluated point {i} drifted from the oracle");
        }
        let guided: Vec<usize> =
            pareto_front(&gpts, axis).into_iter().map(|pos| g.points[pos].0).collect();
        assert_eq!(
            guided, oracle,
            "{ctx}: guided front != exhaustive front on axis {ax} (zero-regret violation)"
        );
    }
}

/// (d): the stats ledger balances against what actually happened.
fn assert_ledger(g: &GuidedSweep, space: usize, ctx: &str) {
    assert_eq!(g.stats.space, space, "{ctx}: space");
    assert_eq!(g.stats.full_evals, g.points.len(), "{ctx}: full-eval ledger");
    assert!(g.stats.full_evals <= space, "{ctx}: more full evals than configs");
    let rung_partials: usize = g.stats.rung_reports.iter().map(|r| r.entered).sum();
    assert_eq!(g.stats.partial_evals, rung_partials, "{ctx}: partial-eval ledger");
    if g.stats.degenerate {
        assert_eq!(g.stats.partial_evals, 0, "{ctx}: degenerate runs score no prefixes");
        assert_eq!(g.stats.full_evals, space, "{ctx}: degenerate runs sweep everything");
    }
    // Indices ascend and are unique — the artifact contract.
    assert!(
        g.points.windows(2).all(|w| w[0].0 < w[1].0),
        "{ctx}: point indices must ascend"
    );
}

#[test]
fn guided_matches_the_exhaustive_oracle_on_60_random_spaces() {
    for seed in 0..60u64 {
        let space = RUNG_THRESHOLD + (seed as usize * 13) % 40;
        let n = 8 + (seed as usize % 5) * 8;
        let land = Landscape::random(seed, space, n);
        let opts = GuidedOpts {
            rungs: 2 + (seed as usize % 3),
            eta: 2 + (seed as usize % 3),
            seed,
            max_alive: None,
        };
        let g = land.run(&opts);
        let ctx = format!("seed {seed} (space {space}, n {n}, {opts:?})");
        assert_oracle_agreement(&land, &g, &ctx);
        assert_ledger(&g, space, &ctx);
    }
}

#[test]
fn guided_runs_are_byte_identical_under_a_fixed_seed() {
    for seed in [0u64, 9, 77, 0xD5E] {
        let land = Landscape::random(seed.wrapping_mul(31).wrapping_add(5), 30, 24);
        let opts = GuidedOpts { rungs: 3, eta: 2, seed, max_alive: None };
        let a = land.run(&opts);
        let b = land.run(&opts);
        assert_eq!(a, b, "seed {seed}: reruns diverged structurally");
        assert_eq!(
            format!("{a:?}"),
            format!("{b:?}"),
            "seed {seed}: reruns diverged byte-for-byte"
        );
    }
}

#[test]
fn tiny_spaces_degenerate_to_the_exact_exhaustive_sweep() {
    for seed in 100..110u64 {
        let space = 1 + (seed as usize % (RUNG_THRESHOLD - 1));
        let land = Landscape::random(seed, space, 12);
        let g = land.run(&GuidedOpts { rungs: 3, eta: 2, seed, max_alive: None });
        let ctx = format!("seed {seed} (space {space})");
        assert!(g.stats.degenerate, "{ctx}: sub-threshold space must degenerate");
        let all = land.exhaustive();
        assert_eq!(g.points.len(), all.len(), "{ctx}");
        for (i, p) in &g.points {
            assert_eq!(p, &all[*i], "{ctx}: degenerate sweep must be bit-identical");
        }
        assert_ledger(&g, space, &ctx);
    }
}

#[test]
fn strictly_fewer_full_evals_on_designed_landscapes() {
    // Rung accounting: whenever one configuration is cheapest on every
    // axis *and* correct on the whole eval set while everything else
    // misses the entire first half, the guided run must certify
    // dominance from the half-set rung and skip full evaluation of
    // most of the space. The exhaustive sweep always evaluates
    // `space`, so this is the strict-savings half of the contract.
    for seed in 0..8u64 {
        let space = RUNG_THRESHOLD + 11 + (seed as usize % 17);
        let n = 16;
        let mut rng = Rng::new(seed);
        let costs: Vec<CostVec> = (0..space as u64)
            .map(|i| CostVec {
                cycles: 10 + i * (5 + rng.below(4)),
                mac: 20 + i * (3 + rng.below(4)),
                mem: 30 + i * (7 + rng.below(4)),
            })
            .collect();
        let correct: Vec<Vec<bool>> = (0..space)
            .map(|i| {
                (0..n)
                    .map(|j| i == 0 || (j >= n / 2 && rng.below(3) == 0))
                    .collect()
            })
            .collect();
        let land = Landscape { costs, n, correct };
        let opts = GuidedOpts { rungs: 3, eta: 2, seed, max_alive: None };
        let g = land.run(&opts);
        let ctx = format!("seed {seed} (space {space})");
        assert_oracle_agreement(&land, &g, &ctx);
        assert!(
            g.stats.full_evals < space,
            "{ctx}: no savings — {} full evals over a {space}-config space",
            g.stats.full_evals
        );
        assert!(g.stats.pruned + g.stats.halved > 0, "{ctx}: nothing was ever dropped");
    }
}

// ------------------------------------- (e) through the coordinator ---

fn host_coordinator(seed: u64) -> Coordinator {
    let model = load_or_fallback(Path::new("/nonexistent"), "lenet5", seed).unwrap();
    let test = model.test.clone();
    Coordinator::new(model, Box::new(HostEval { test }), 2).unwrap()
}

#[test]
fn coordinator_guided_front_equals_the_exhaustive_front() {
    let seed = 11;
    let eval_n = 8;
    let exhaustive = host_coordinator(seed);
    let n_layers = exhaustive.analysis.layers.len();
    let configs = enumerate(n_layers, &default_pinned(), 27, seed);
    assert!(configs.len() >= RUNG_THRESHOLD, "need a rung-eligible space");
    let oracle = exhaustive.run_sweep(&configs, eval_n).unwrap();

    // A *separate* coordinator instance (fresh caches) for the guided
    // run: the equality must not lean on shared evaluation state.
    let c = host_coordinator(seed);
    let opts = GuidedOpts { rungs: 3, eta: 2, seed, max_alive: None };
    let g = c.sweep_guided(&configs, eval_n, &opts).unwrap();

    assert!(g.stats.full_evals <= configs.len());
    assert_eq!(g.stats.full_evals, g.points.len());
    for (ax, axis) in AXES.iter().enumerate() {
        let ofront: Vec<usize> = pareto_front(&oracle, axis);
        let gpts: Vec<EvalPoint> = g.points.iter().map(|(_, p)| p.clone()).collect();
        let gfront: Vec<usize> =
            pareto_front(&gpts, axis).into_iter().map(|pos| g.points[pos].0).collect();
        assert_eq!(gfront, ofront, "axis {ax}: guided front != exhaustive front");
        for &i in &ofront {
            let (_, gp) = g
                .points
                .iter()
                .find(|(gi, _)| *gi == i)
                .unwrap_or_else(|| panic!("axis {ax}: true Pareto point {i} was pruned"));
            // Bit-identical: guided full evaluations ride the same
            // cached `evaluate` path as the exhaustive sweep.
            assert_eq!(
                gp.accuracy.to_bits(),
                oracle[i].accuracy.to_bits(),
                "axis {ax}: point {i} accuracy drifted"
            );
            assert_eq!(gp, &oracle[i], "axis {ax}: point {i} drifted");
        }
    }

    // Determinism across coordinator instances, byte-for-byte.
    let again = host_coordinator(seed).sweep_guided(&configs, eval_n, &opts).unwrap();
    assert_eq!(again, g, "guided sweep is not deterministic across instances");
    assert_eq!(format!("{again:?}"), format!("{g:?}"));

    // The partial-eval metric counts the cache-bypassing rung scores.
    let partials = c.metrics.partial_evals.load(std::sync::atomic::Ordering::Relaxed);
    assert_eq!(partials as usize, g.stats.partial_evals, "partial-eval metric ledger");
}

/// (f): the streamed `ConfigSpace` paths reproduce the materialized
/// slice paths byte-for-byte, and the streamed guided front is the
/// materialized exhaustive front on every cost axis.
#[test]
fn streamed_space_paths_are_byte_identical_to_the_slice_paths() {
    let seed = 11;
    let eval_n = 8;
    let c = host_coordinator(seed);
    let n_layers = c.analysis.layers.len();
    let space = ConfigSpace::new(n_layers, &default_pinned(), 27, seed);
    let configs = enumerate(n_layers, &default_pinned(), 27, seed);
    assert_eq!(space.len(), configs.len(), "space/slice cardinality drifted");

    // Exhaustive: streaming the space through the bounded pipeline
    // must reproduce the slice sweep bit-for-bit. Fresh coordinator
    // instances so the equality never leans on shared caches.
    let by_slice = c.run_sweep(&configs, eval_n).unwrap();
    let by_space = host_coordinator(seed).run_sweep_space(&space, eval_n).unwrap();
    assert_eq!(by_slice.len(), by_space.len());
    for (i, (a, b)) in by_slice.iter().zip(&by_space).enumerate() {
        assert_eq!(
            a.accuracy.to_bits(),
            b.accuracy.to_bits(),
            "point {i}: streamed accuracy drifted"
        );
        assert_eq!(a, b, "point {i}: streamed exhaustive sweep drifted from the slice sweep");
    }

    // Guided: the index-streaming driver must reproduce the slice
    // driver bit-for-bit — same indices, same points, same ledger.
    let opts = GuidedOpts { rungs: 3, eta: 2, seed, max_alive: None };
    let gs = host_coordinator(seed).sweep_guided(&configs, eval_n, &opts).unwrap();
    let gl = host_coordinator(seed).sweep_guided_space(&space, eval_n, &opts).unwrap();
    assert_eq!(gl, gs, "streamed guided sweep drifted from the slice sweep");
    assert_eq!(format!("{gl:?}"), format!("{gs:?}"));

    // And the streamed guided front equals the materialized exhaustive
    // front on every axis — the end-to-end zero-regret contract of the
    // lazy space.
    for (ax, axis) in AXES.iter().enumerate() {
        let ofront: Vec<usize> = pareto_front(&by_slice, axis);
        let gpts: Vec<EvalPoint> = gl.points.iter().map(|(_, p)| p.clone()).collect();
        let gfront: Vec<usize> =
            pareto_front(&gpts, axis).into_iter().map(|pos| gl.points[pos].0).collect();
        assert_eq!(gfront, ofront, "axis {ax}: streamed guided front != exhaustive front");
    }
}

/// A designed accuracy backend: the all-2-bit tail configuration is
/// perfectly accurate, every other configuration misses the entire
/// first half of the (virtual) eval set. Keyed off `qm.bits`, so it
/// exercises the real coordinator plumbing — quantization, the
/// cache-bypassing partial path, the cached full path — with a
/// landscape whose savings are provable.
struct DesignedEval {
    n: usize,
}

impl AccuracyEval for DesignedEval {
    fn evaluate(&self, qm: &QModel, n: usize) -> Result<EvalReport> {
        let n = n.min(self.n);
        let star = qm.bits.iter().skip(1).all(|&b| b == 2);
        let h: u64 = qm.bits.iter().fold(7u64, |a, &b| a.wrapping_mul(31).wrapping_add(b as u64));
        let hits = (0..n)
            .filter(|&j| star || (j >= self.n / 2 && (h + j as u64) % 3 == 0))
            .count();
        Ok(EvalReport { accuracy: hits as f32 / n as f32, ..EvalReport::default() })
    }

    fn name(&self) -> &'static str {
        "host"
    }

    fn eval_len(&self) -> usize {
        self.n
    }
}

#[test]
fn coordinator_guided_saves_full_evals_on_a_designed_landscape() {
    let seed = 5;
    let model = load_or_fallback(Path::new("/nonexistent"), "lenet5", seed).unwrap();
    let c = Coordinator::new(model, Box::new(DesignedEval { n: 16 }), 2).unwrap();
    let n_layers = c.analysis.layers.len();
    let configs = enumerate(n_layers, &default_pinned(), 27, seed);

    // Premise: the all-2 tail config is at most as costly as every
    // other config on every analytic axis (packing and the cycle model
    // are monotone in lanes). If this ever breaks, the designed
    // landscape no longer proves savings — fail loudly here, not in
    // the savings assertion below.
    let star = configs
        .iter()
        .position(|cfg| cfg.iter().skip(1).all(|&b| b == 2))
        .expect("enumeration contains the all-2 tail config");
    let cost_of = |cfg: &mpnn::dse::Config| {
        let t = c.cycle_model.config_total(cfg);
        (t.cycles, total_mac_instructions(&c.analysis, cfg), t.mem_accesses)
    };
    let sc = cost_of(&configs[star]);
    for (i, cfg) in configs.iter().enumerate() {
        let cc = cost_of(cfg);
        assert!(
            sc.0 <= cc.0 && sc.1 <= cc.1 && sc.2 <= cc.2,
            "premise broken: config #{i} {cfg:?} {cc:?} undercuts the all-2 config {sc:?}"
        );
    }

    let g = c.sweep_guided(&configs, 16, &GuidedOpts { rungs: 3, eta: 2, seed, max_alive: None }).unwrap();
    assert!(
        g.stats.full_evals < configs.len(),
        "no savings through the coordinator: {}/{} full evals",
        g.stats.full_evals,
        configs.len()
    );
    assert!(g.stats.pruned + g.stats.halved > 0, "nothing was ever dropped");
    // And the star config tops the front on every axis.
    let gpts: Vec<EvalPoint> = g.points.iter().map(|(_, p)| p.clone()).collect();
    for axis in AXES {
        let front: Vec<usize> =
            pareto_front(&gpts, axis).into_iter().map(|pos| g.points[pos].0).collect();
        assert!(front.contains(&star), "all-2 config missing from the front {front:?}");
    }
}
