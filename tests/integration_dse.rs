//! DSE + coordinator integration over the artifact-free fallback path:
//! enumeration invariants, sweep behaviour, Pareto/threshold structure
//! and the energy model composition — no PJRT required.

use mpnn::coordinator::{Coordinator, HostEval};
use mpnn::dse::pareto::pareto_front;
use mpnn::dse::{default_pinned, enumerate, select_under_threshold};
use mpnn::energy::{ASIC_BASELINE, ASIC_MODIFIED};
use mpnn::models::format::load_or_fallback;
use std::path::Path;

fn coordinator(name: &str) -> Coordinator {
    let model = load_or_fallback(Path::new("/nonexistent"), name, 3).unwrap();
    let test = model.test.clone();
    Coordinator::new(model, Box::new(HostEval { test }), 2).unwrap()
}

#[test]
fn lenet_sweep_pareto_and_energy_compose() {
    let c = coordinator("lenet5");
    let n = c.analysis.layers.len();
    let configs = enumerate(n, &default_pinned(), 27, 5);
    let pts = c.run_sweep(&configs, 16).unwrap();
    assert_eq!(pts.len(), 27);

    // Pareto front invariants.
    let front = pareto_front(&pts, |p| p.cycles);
    assert!(!front.is_empty());
    for w in front.windows(2) {
        assert!(pts[w[0]].cycles <= pts[w[1]].cycles);
        assert!(pts[w[0]].accuracy < pts[w[1]].accuracy);
    }

    // Cycles ordering: uniform-2 fastest, uniform-8 slowest among
    // uniform configs.
    let find = |b: u32| pts.iter().find(|p| p.config[1..].iter().all(|&x| x == b)).unwrap();
    assert!(find(2).cycles < find(4).cycles);
    assert!(find(4).cycles < find(8).cycles);

    // Threshold selection (loose threshold must select something).
    let sel = select_under_threshold(&pts, 0.0, 1.0).unwrap();
    assert!(pts[sel].cycles <= pts.iter().map(|p| p.cycles).min().unwrap());

    // Energy composition: faster config -> better GOP/s/W on the
    // modified platform than baseline-on-baseline.
    let macs = c.analysis.total_macs;
    let base = c.cycle_model.baseline_total().cycles;
    let fast = pts[sel].cycles;
    let rb = ASIC_BASELINE.evaluate(macs, base);
    let rm = ASIC_MODIFIED.evaluate(macs, fast);
    assert!(rm.gops_per_w > rb.gops_per_w);
}

#[test]
fn quantized_assembly_matches_direct_quantization() {
    // The coordinator's per-(layer,width) cache must assemble exactly
    // what dse::quantize_config computes from scratch.
    let c = coordinator("lenet5");
    let n = c.analysis.layers.len();
    let cfg = vec![8, 4, 2, 4, 8][..n.min(5)].to_vec();
    let cfg = if cfg.len() == n { cfg } else { vec![4; n] };
    let a = c.quantized(&cfg);
    let b = mpnn::dse::quantize_config(&c.model.spec, &c.model.params, &c.model.sites, &cfg);
    for (la, lb) in a.layers.iter().zip(&b.layers) {
        assert_eq!(la.qw, lb.qw);
        assert_eq!(la.bias, lb.bias);
        assert_eq!(la.rq, lb.rq);
    }
}

#[test]
fn mem_accesses_reduce_with_width_fig4_structure() {
    let c = coordinator("cifar_cnn");
    let cm = &c.cycle_model;
    for l in 0..c.analysis.layers.len() {
        let base = cm.baseline[l].mem_accesses;
        let w8 = cm.layer_cost(l, 8).mem_accesses;
        let w2 = cm.layer_cost(l, 2).mem_accesses;
        assert!(w8 < base, "layer {l}");
        assert!(w2 < w8, "layer {l}");
        // The paper's ≈85% claim holds on wide conv layers; globally we
        // require at least 50% at 8-bit and 65% at 2-bit per layer.
        assert!((w8 as f64) < 0.5 * base as f64, "layer {l}: {w8} vs {base}");
        assert!((w2 as f64) < 0.35 * base as f64, "layer {l}: {w2} vs {base}");
    }
}

#[test]
fn enumerate_respects_budget_and_pinning() {
    for (layers, budget) in [(5usize, 50usize), (28, 64), (47, 100)] {
        let cfgs = enumerate(layers, &[0], budget, 9);
        assert!(cfgs.len() <= budget);
        assert!(cfgs.iter().all(|c| c.len() == layers && c[0] == 8));
        // No duplicates.
        let mut s = cfgs.clone();
        s.sort();
        s.dedup();
        assert_eq!(s.len(), cfgs.len());
    }
}
