//! Property tests over the ISA substrate (offline environment — these
//! use the crate's deterministic RNG in place of proptest).

use mpnn::isa::decode::decode;
use mpnn::isa::encode::encode;
use mpnn::isa::*;
use mpnn::rng::Rng;

/// Generate a random well-formed instruction.
fn random_instr(rng: &mut Rng) -> Instr {
    let reg = |r: &mut Rng| (r.below(32)) as Reg;
    let alu_ops = [
        AluOp::Add,
        AluOp::Sub,
        AluOp::Sll,
        AluOp::Slt,
        AluOp::Sltu,
        AluOp::Xor,
        AluOp::Srl,
        AluOp::Sra,
        AluOp::Or,
        AluOp::And,
    ];
    let mul_ops = [
        MulOp::Mul,
        MulOp::Mulh,
        MulOp::Mulhsu,
        MulOp::Mulhu,
        MulOp::Div,
        MulOp::Divu,
        MulOp::Rem,
        MulOp::Remu,
    ];
    let br_ops =
        [BranchOp::Beq, BranchOp::Bne, BranchOp::Blt, BranchOp::Bge, BranchOp::Bltu, BranchOp::Bgeu];
    match rng.below(12) {
        0 => Instr::Lui { rd: reg(rng), imm: (rng.next_u32() as i32) & !0xfff },
        1 => Instr::Auipc { rd: reg(rng), imm: (rng.next_u32() as i32) & !0xfff },
        2 => Instr::Jal { rd: reg(rng), offset: (rng.range_i32(-(1 << 19), (1 << 19) - 1)) * 2 },
        3 => Instr::Jalr { rd: reg(rng), rs1: reg(rng), offset: rng.range_i32(-2048, 2047) },
        4 => Instr::Branch {
            op: br_ops[rng.below(6) as usize],
            rs1: reg(rng),
            rs2: reg(rng),
            offset: rng.range_i32(-2048, 2047) * 2,
        },
        5 => Instr::Load {
            op: [LoadOp::Lb, LoadOp::Lh, LoadOp::Lw, LoadOp::Lbu, LoadOp::Lhu]
                [rng.below(5) as usize],
            rd: reg(rng),
            rs1: reg(rng),
            offset: rng.range_i32(-2048, 2047),
        },
        6 => Instr::Store {
            op: [StoreOp::Sb, StoreOp::Sh, StoreOp::Sw][rng.below(3) as usize],
            rs1: reg(rng),
            rs2: reg(rng),
            offset: rng.range_i32(-2048, 2047),
        },
        7 => {
            let op = alu_ops[rng.below(10) as usize];
            let imm = match op {
                AluOp::Sll | AluOp::Srl | AluOp::Sra => rng.range_i32(0, 31),
                AluOp::Sub => return Instr::Op { op, rd: reg(rng), rs1: reg(rng), rs2: reg(rng) },
                _ => rng.range_i32(-2048, 2047),
            };
            Instr::OpImm { op, rd: reg(rng), rs1: reg(rng), imm }
        }
        8 => Instr::Op {
            op: alu_ops[rng.below(10) as usize],
            rd: reg(rng),
            rs1: reg(rng),
            rs2: reg(rng),
        },
        9 => Instr::MulDiv {
            op: mul_ops[rng.below(8) as usize],
            rd: reg(rng),
            rs1: reg(rng),
            rs2: reg(rng),
        },
        10 => {
            let mode = [MacMode::W8, MacMode::W4, MacMode::W2][rng.below(3) as usize];
            let max_rs1 = 32 - mode.activation_regs();
            Instr::NnMac {
                mode,
                rd: reg(rng),
                rs1: rng.below(max_rs1 as u64) as Reg,
                rs2: reg(rng),
            }
        }
        _ => Instr::Csr {
            op: [CsrOp::Rw, CsrOp::Rs, CsrOp::Rc][rng.below(3) as usize],
            rd: reg(rng),
            rs1: reg(rng),
            csr: rng.below(4096) as u16,
        },
    }
}

#[test]
fn encode_decode_round_trip_10k() {
    let mut rng = Rng::new(0x15A);
    for i in 0..10_000 {
        let instr = random_instr(&mut rng);
        let word = encode(instr);
        let back = decode(word).unwrap_or_else(|e| panic!("case {i}: {instr:?} -> {e}"));
        assert_eq!(back, instr, "case {i}: word {word:#010x}");
    }
}

#[test]
fn decode_never_panics_on_random_words() {
    let mut rng = Rng::new(0xF00D);
    for _ in 0..100_000 {
        let w = rng.next_u32();
        let _ = decode(w); // must return Ok or Err, never panic
    }
}

#[test]
fn disasm_total_on_valid_instructions() {
    let mut rng = Rng::new(7);
    for _ in 0..2_000 {
        let instr = random_instr(&mut rng);
        let text = mpnn::isa::disasm::disasm(instr);
        assert!(!text.is_empty());
    }
}

#[test]
fn nn_mac_ref_invariants() {
    use mpnn::isa::custom::*;
    let mut rng = Rng::new(99);
    for _ in 0..2_000 {
        let mode = [MacMode::W8, MacMode::W4, MacMode::W2][rng.below(3) as usize];
        let n = mode.weights_per_word() as usize;
        let w: Vec<i8> = (0..n).map(|_| rng.int_bits(mode.weight_bits())).collect();
        let word = pack_weights(mode, &w);
        // Round trip.
        assert_eq!(unpack_weights(mode, word), w);
        let acts: Vec<u32> = (0..mode.activation_regs()).map(|_| rng.next_u32()).collect();
        // Zero weights -> accumulator unchanged.
        let acc = rng.next_u32();
        assert_eq!(nn_mac_ref(mode, acc, &acts, 0), acc);
        // Linearity in the accumulator.
        let r0 = nn_mac_ref(mode, 0, &acts, word);
        let r1 = nn_mac_ref(mode, acc, &acts, word);
        assert_eq!(r1, acc.wrapping_add(r0));
    }
}

#[test]
fn assembler_round_trips_through_encoder() {
    // assemble -> encode -> decode -> same instruction stream.
    use mpnn::asm::Asm;
    use mpnn::isa::reg;
    let mut a = Asm::new();
    let top = a.here("top");
    a.li(reg::A0, 123456);
    a.lw(reg::A1, reg::SP, 16);
    a.nn_mac(MacMode::W4, reg::A0, reg::A2, reg::A1);
    a.bne(reg::A0, reg::ZERO, top);
    a.halt();
    let prog = a.assemble();
    for ins in &prog {
        assert_eq!(decode(encode(*ins)).unwrap(), *ins);
    }
}
