//! Plan-cache accounting acceptance: a DSE sweep through the
//! ISS-backed coordinator compiles each `(model, config)` execution
//! plan **exactly once**, observed via the cache stats on the global
//! [`SessionStats`](mpnn::sim::session::SessionStats).
//!
//! This file deliberately holds a single `#[test]`: integration-test
//! files are separate processes, so this test is the sole owner of the
//! process-global `plan_compiles` / `plan_hits` counters and can
//! assert them exactly (the sibling `tests/plan_equivalence.rs` checks
//! the same contract structurally, via `Arc` identity, where counter
//! exactness would race with concurrent tests).

use mpnn::coordinator::{Coordinator, IssEval};
use mpnn::models::format::LoadedModel;
use mpnn::models::infer::{calibrate, random_params};
use mpnn::models::sim_exec::{modes_for, run_model};
use mpnn::models::synthetic::{generate, generate_split};
use mpnn::models::{LayerSpec, ModelSpec, Node};
use mpnn::sim::MacUnitConfig;
use std::sync::atomic::Ordering;

fn tiny_model(seed: u64) -> LoadedModel {
    let spec = ModelSpec {
        name: "tiny",
        input: [8, 8, 3],
        num_classes: 4,
        nodes: vec![
            Node::Layer(LayerSpec::Conv { cout: 8, k: 3, stride: 1, pad: 1, relu: true }),
            Node::Layer(LayerSpec::MaxPool2),
            Node::Layer(LayerSpec::Dense { out: 4, relu: false }),
        ],
    };
    let params = random_params(&spec, seed);
    let calib = generate(seed ^ 1, 8, spec.input, spec.num_classes, 0.4);
    let sites = calibrate(&spec, &params, &calib.images[..4]);
    let test = generate_split(seed ^ 1, seed ^ 2, 8, spec.input, spec.num_classes, 0.4);
    LoadedModel { spec, params, sites, float_acc: 1.0, test }
}

#[test]
fn iss_sweep_compiles_each_config_plan_exactly_once() {
    let model = tiny_model(77);
    let test = model.test.clone();
    let c = Coordinator::new(model, Box::new(IssEval::new(test, 2)), 2).unwrap();
    let n = c.analysis.layers.len();

    let stats = &mpnn::sim::SimSession::global().stats;
    let compiles0 = stats.plan_compiles.load(Ordering::Relaxed);
    let hits0 = stats.plan_hits.load(Ordering::Relaxed);

    // Four distinct configurations plus one duplicate: the duplicate is
    // served from the coordinator's result cache and never reaches the
    // evaluator, so exactly four plans compile.
    let configs = vec![
        vec![8u32; n],
        vec![4u32; n],
        vec![2u32; n],
        {
            let mut m = vec![8u32; n];
            m[n - 1] = 2;
            m
        },
        vec![8u32; n], // duplicate
    ];
    let pts = c.run_sweep(&configs, 4).unwrap();
    assert_eq!(pts.len(), configs.len());
    for p in &pts {
        assert!(p.iss_cycles.unwrap() > 0);
        assert_eq!(p.divergence, Some(0.0), "plan-driven host/ISS paths must agree");
    }

    let compiles_sweep = stats.plan_compiles.load(Ordering::Relaxed) - compiles0;
    let hits_sweep = stats.plan_hits.load(Ordering::Relaxed) - hits0;
    assert_eq!(compiles_sweep, 4, "one plan per distinct (model, config)");
    // IssEval lowers once per config and replays the Arc directly, so
    // the sweep itself produces no lookups — except when the duplicate
    // config races its first instance past the coordinator's result
    // cache, in which case the losing evaluation is a plan-cache hit.
    assert!(hits_sweep <= 1, "unexpected plan-cache traffic during the sweep: {hits_sweep}");

    // Re-sweeping the same configs is entirely cache-served at the
    // coordinator layer: no new plans, no new lookups.
    let hits_after_sweep = stats.plan_hits.load(Ordering::Relaxed);
    let again = c.run_sweep(&configs, 4).unwrap();
    assert_eq!(again.len(), pts.len());
    assert_eq!(stats.plan_compiles.load(Ordering::Relaxed) - compiles0, 4);
    assert_eq!(stats.plan_hits.load(Ordering::Relaxed), hits_after_sweep);

    // A direct ISS run of a swept configuration resolves the *same*
    // plan through the cache — content-addressed, even though this
    // QModel is assembled by a different code path (coordinator qcache
    // vs quantize_model): a hit, not a fifth compile.
    let qm = c.quantized(&vec![4u32; n]);
    let input =
        mpnn::models::infer::quantize_input(&qm, &c.model.test.images[0]);
    run_model(&qm, &input, &modes_for(&qm), MacUnitConfig::full()).unwrap();
    assert_eq!(
        stats.plan_compiles.load(Ordering::Relaxed) - compiles0,
        4,
        "direct run of a swept config must not recompile"
    );
    assert!(stats.plan_hits.load(Ordering::Relaxed) - hits0 >= 1);
}
