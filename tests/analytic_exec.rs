//! Analytic fast-path acceptance: [`ExecMode::Analytic`] is
//! **observationally identical** to the full ISS execution.
//!
//! 1. Property: across the synthetic zoo models and seeded-random
//!    mixed-precision configurations, an analytic `run_plan_batch` is
//!    bit-identical to per-input ISS runs — logits AND per-layer
//!    cycle / memory-access / instret counters. The analytic path may
//!    only change *how much* simulation happens, never a single
//!    reported number.
//! 2. The seeded audit-element selection ([`audit_indices`]) is a pure
//!    function of `(seed, n, every)`: repeated calls agree, prefixes
//!    agree across shard sizes, and the degenerate cadences (0 = off,
//!    1 = everything) behave as documented.
//! 3. A perturbed cost cache **fails typed, never silently**: poisoning
//!    one kernel's cached counters makes an audited [`AnalyticEval`]
//!    return the "analytic audit mismatch" error and bumps the
//!    `audit_mismatches` session counter.
//!
//! Counter *exactness* for the session-global `runs` statistic lives in
//! `tests/analytic_stats.rs`, which owns its own process.

use mpnn::coordinator::{AccuracyEval, AnalyticEval};
use mpnn::models::infer::{calibrate, quantize_input, quantize_model, random_params};
use mpnn::models::plan::{plan_for, Step};
use mpnn::models::sim_exec::{
    audit_indices, cost_key_for, modes_for, run_plan, run_plan_batch, ExecMode,
};
use mpnn::models::synthetic::generate;
use mpnn::models::{zoo, LayerSpec, ModelSpec, Node};
use mpnn::nn::tensor::Tensor;
use mpnn::rng::Rng;
use mpnn::sim::{MacUnitConfig, SimSession};
use std::sync::atomic::Ordering;

fn toy_residual_model() -> ModelSpec {
    ModelSpec {
        name: "toy",
        input: [8, 8, 3],
        num_classes: 4,
        nodes: vec![
            Node::Layer(LayerSpec::Conv { cout: 8, k: 3, stride: 1, pad: 1, relu: true }),
            Node::Layer(LayerSpec::MaxPool2),
            Node::Residual(vec![
                LayerSpec::Conv { cout: 16, k: 1, stride: 1, pad: 0, relu: true },
                LayerSpec::Depthwise { k: 3, stride: 1, pad: 1, relu: true },
                LayerSpec::Conv { cout: 8, k: 1, stride: 1, pad: 0, relu: false },
            ]),
            Node::Layer(LayerSpec::AvgPoolGlobal),
            Node::Layer(LayerSpec::Dense { out: 4, relu: false }),
        ],
    }
}

fn toy_dw_stride_model() -> ModelSpec {
    ModelSpec {
        name: "toy_dw",
        input: [9, 9, 3],
        num_classes: 3,
        nodes: vec![
            Node::Layer(LayerSpec::Conv { cout: 6, k: 3, stride: 2, pad: 1, relu: true }),
            Node::Layer(LayerSpec::Depthwise { k: 3, stride: 2, pad: 1, relu: true }),
            Node::Layer(LayerSpec::Dense { out: 8, relu: true }),
            Node::Layer(LayerSpec::Dense { out: 3, relu: false }),
        ],
    }
}

fn random_bits(rng: &mut Rng, n: usize) -> Vec<u32> {
    (0..n).map(|_| [8u32, 4, 2][rng.below(3) as usize]).collect()
}

/// Analytic batch vs per-input ISS: bit-identical logits and per-layer
/// counters for every batch element.
fn check_analytic_matches_iss(spec: &ModelSpec, bits: &[u32], seed: u64) {
    let n = mpnn::models::analyze(spec).layers.len();
    assert_eq!(bits.len(), n);
    let params = random_params(spec, seed);
    let ds = generate(seed ^ 0x5A, 5, spec.input, spec.num_classes, 0.4);
    let sites = calibrate(spec, &params, &ds.images[..2]);
    let qm = quantize_model(spec, &params, &sites, bits);
    let mac = MacUnitConfig::full();
    let inputs: Vec<Tensor<i8>> = ds.images.iter().map(|im| quantize_input(&qm, im)).collect();

    let plan = plan_for(&qm, &modes_for(&qm)).unwrap();
    let analytic = run_plan_batch(&plan, &inputs, mac, ExecMode::Analytic, 3).unwrap();
    assert_eq!(analytic.len(), inputs.len());
    for (mi, (input, arun)) in inputs.iter().zip(&analytic).enumerate() {
        let iss = run_plan(&plan, input, mac, ExecMode::Iss, None).unwrap();
        assert_eq!(arun.logits, iss.logits, "{} bits {bits:?} input {mi}: logits", spec.name);
        assert_eq!(arun.layers.len(), iss.layers.len());
        for (a, b) in arun.layers.iter().zip(&iss.layers) {
            assert_eq!(a.layer, b.layer);
            assert_eq!(a.mode, b.mode);
            assert_eq!(
                a.perf, b.perf,
                "{} bits {bits:?} input {mi} layer {}: cache-served counters must equal \
                 an ISS measurement",
                spec.name, a.layer
            );
        }
        assert_eq!(arun.total_cycles(), iss.total_cycles());
        assert_eq!(arun.total_accesses(), iss.total_accesses());
        assert_eq!(arun.total_instret(), iss.total_instret());
    }
}

#[test]
fn analytic_matches_iss_on_toy_residual() {
    let spec = toy_residual_model();
    let n = mpnn::models::analyze(&spec).layers.len();
    check_analytic_matches_iss(&spec, &vec![8; n], 700);
    check_analytic_matches_iss(&spec, &vec![2; n], 701);
    let mut rng = Rng::new(0xA7_01);
    let bits = random_bits(&mut rng, n);
    check_analytic_matches_iss(&spec, &bits, 702);
}

#[test]
fn analytic_matches_iss_on_dw_stride_geometry() {
    let spec = toy_dw_stride_model();
    let n = mpnn::models::analyze(&spec).layers.len();
    check_analytic_matches_iss(&spec, &vec![4; n], 710);
    let mut rng = Rng::new(0xA7_02);
    let bits = random_bits(&mut rng, n);
    check_analytic_matches_iss(&spec, &bits, 711);
}

#[test]
fn analytic_matches_iss_on_lenet5() {
    let spec = zoo::lenet5();
    let n = mpnn::models::analyze(&spec).layers.len();
    check_analytic_matches_iss(&spec, &vec![4; n], 720);
    let mut rng = Rng::new(0xA7_03);
    let bits = random_bits(&mut rng, n);
    check_analytic_matches_iss(&spec, &bits, 721);
}

// ------------------------------------------------- audit selection ---

#[test]
fn audit_selection_is_deterministic_and_strided() {
    for seed in [0u64, 1, 0xDEAD_BEEF, u64::MAX] {
        for every in [1usize, 2, 3, 7] {
            let a = audit_indices(seed, 16, every);
            let b = audit_indices(seed, 16, every);
            assert_eq!(a, b, "selection must be a pure function of (seed, n, every)");
            assert!(!a.is_empty());
            assert!(a[0] < every, "phase must land inside the first stride");
            for w in a.windows(2) {
                assert_eq!(w[1] - w[0], every, "every {every}th element, exactly");
            }
        }
    }
    // Different seeds move the phase (the audit is sampled, not fixed
    // to element 0 forever).
    let phases: std::collections::BTreeSet<usize> =
        (0..64u64).map(|s| audit_indices(s, 16, 7)[0]).collect();
    assert!(phases.len() > 1, "seed must influence the audit phase");
}

#[test]
fn audit_selection_agrees_across_shard_sizes() {
    // Shards of the same element order audit the same elements: the
    // global selection restricted to a shard's prefix IS the shard's
    // own selection — no shard strategy can change which inputs get
    // replayed on the ISS.
    for seed in [3u64, 0xC0FFEE] {
        for every in [2usize, 5] {
            let whole = audit_indices(seed, 32, every);
            let prefix = audit_indices(seed, 16, every);
            let cut: Vec<usize> = whole.iter().copied().filter(|&i| i < 16).collect();
            assert_eq!(prefix, cut, "prefix selection must agree with the global one");
        }
    }
}

#[test]
fn audit_degenerate_cadences() {
    assert!(audit_indices(9, 16, 0).is_empty(), "every = 0 disables auditing");
    assert!(audit_indices(9, 0, 3).is_empty(), "empty batch audits nothing");
    // every = 1 is the full-ISS differential check CI's byte-identity
    // smoke relies on: every element, regardless of seed.
    for seed in [0u64, 42, u64::MAX] {
        assert_eq!(audit_indices(seed, 16, 1), (0..16).collect::<Vec<_>>());
    }
}

// ---------------------------------------------- perturbation audit ---

/// Geometry used by no other test in this binary, so the poisoned
/// [`CostKey`](mpnn::sim::session::CostKey) below cannot collide with a
/// key the bit-identity properties above legitimately cached.
fn perturb_model() -> ModelSpec {
    ModelSpec {
        name: "toy_perturb",
        input: [6, 6, 3],
        num_classes: 3,
        nodes: vec![
            Node::Layer(LayerSpec::Conv { cout: 5, k: 3, stride: 1, pad: 1, relu: true }),
            Node::Layer(LayerSpec::Dense { out: 3, relu: false }),
        ],
    }
}

#[test]
fn perturbed_cost_cache_trips_the_audit_with_a_typed_error() {
    let spec = perturb_model();
    let n = mpnn::models::analyze(&spec).layers.len();
    let params = random_params(&spec, 730);
    let ds = generate(731, 6, spec.input, spec.num_classes, 0.4);
    let sites = calibrate(&spec, &params, &ds.images[..2]);
    let qm = quantize_model(&spec, &params, &sites, &vec![4; n]);
    let mac = MacUnitConfig::full();
    let session = SimSession::global();

    let mut ev = AnalyticEval::new(ds.clone(), 2);
    ev.audit_every = 1;
    ev.audit_seed = 7;

    // Healthy run first: the cache fills from real ISS measurements and
    // the full-batch audit passes.
    ev.evaluate(&qm, ds.images.len()).expect("unperturbed analytic eval must audit clean");

    // Poison the conv step's cached counters through the documented
    // overwrite hook. The next analytic run serves the poisoned cycle
    // count; its ISS replay cannot.
    let plan = plan_for(&qm, &modes_for(&qm)).unwrap();
    let ks = plan
        .steps
        .iter()
        .find_map(|s| match s {
            Step::Kernel(ks) => Some(ks),
            _ => None,
        })
        .expect("plan has a kernel step");
    let key = cost_key_for(ks, mac);
    let mut perf = session.costs.get(&key).expect("healthy run must have cached the conv cost");
    perf.cycles += 1;
    session.costs.insert(key, perf);

    let mismatches0 = session.stats.audit_mismatches.load(Ordering::Relaxed);
    let err = ev
        .evaluate(&qm, ds.images.len())
        .expect_err("a poisoned cost cache must fail the audited evaluation");
    let msg = err.to_string();
    assert!(
        msg.contains("analytic audit mismatch"),
        "mismatch must surface as the typed audit error, got: {msg}"
    );
    assert!(
        session.stats.audit_mismatches.load(Ordering::Relaxed) > mismatches0,
        "audit_mismatches must count the tripped audit"
    );

    // Repair the cache so a hypothetical later analytic user of this
    // exact geometry (none today) would see honest numbers again.
    perf.cycles -= 1;
    session.costs.insert(key, perf);
}
