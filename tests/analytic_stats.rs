//! Analytic-execution accounting acceptance: a cold analytic batch of
//! N inputs runs the ISS **once per unique kernel step** — not
//! steps × N, and not steps × workers — observed via the engine-run
//! counter on the global [`SessionStats`](mpnn::sim::session::SessionStats).
//!
//! This file deliberately holds a single `#[test]`: integration-test
//! files are separate processes, so this test is the sole owner of the
//! process-global `runs` / `analytic_hits` counters and can assert them
//! exactly (the sibling `tests/analytic_exec.rs` checks bit-identity of
//! the analytic results, where counter exactness would race with its
//! concurrent tests).

use mpnn::models::infer::{calibrate, quantize_input, quantize_model, random_params};
use mpnn::models::plan::{plan_for, Step};
use mpnn::models::sim_exec::{modes_for, run_plan_batch, ExecMode};
use mpnn::models::synthetic::generate;
use mpnn::models::{LayerSpec, ModelSpec, Node};
use mpnn::nn::tensor::Tensor;
use mpnn::sim::{MacUnitConfig, SimSession};
use std::sync::atomic::Ordering;

#[test]
fn analytic_batch_runs_the_iss_once_per_unique_kernel_step() {
    let spec = ModelSpec {
        name: "tiny_analytic",
        input: [8, 8, 3],
        num_classes: 4,
        nodes: vec![
            Node::Layer(LayerSpec::Conv { cout: 8, k: 3, stride: 1, pad: 1, relu: true }),
            Node::Layer(LayerSpec::MaxPool2),
            Node::Layer(LayerSpec::Depthwise { k: 3, stride: 1, pad: 1, relu: true }),
            Node::Layer(LayerSpec::Dense { out: 4, relu: false }),
        ],
    };
    let n = mpnn::models::analyze(&spec).layers.len();
    let params = random_params(&spec, 90);
    let ds = generate(91, 8, spec.input, spec.num_classes, 0.4);
    let sites = calibrate(&spec, &params, &ds.images[..3]);
    let qm = quantize_model(&spec, &params, &sites, &vec![4; n]);
    let mac = MacUnitConfig::full();
    let inputs: Vec<Tensor<i8>> = ds.images.iter().map(|im| quantize_input(&qm, im)).collect();
    let batch = inputs.len();

    let plan = plan_for(&qm, &modes_for(&qm)).unwrap();
    let kernel_steps = plan.steps.iter().filter(|s| matches!(s, Step::Kernel(_))).count();
    assert_eq!(kernel_steps, n, "every quantizable layer lowers to one kernel step");

    let stats = &SimSession::global().stats;
    let runs0 = stats.runs.load(Ordering::Relaxed);
    let hits0 = stats.analytic_hits.load(Ordering::Relaxed);

    // Cold batch: the warm-up input misses every kernel step once (one
    // ISS execution each); the other batch - 1 inputs are pure cache
    // hits even with a parallel worker pool.
    let runs =
        run_plan_batch(&plan, &inputs, mac, ExecMode::Analytic, 4).unwrap();
    assert_eq!(runs.len(), batch);
    let iss_execs = stats.runs.load(Ordering::Relaxed) - runs0;
    let hits = stats.analytic_hits.load(Ordering::Relaxed) - hits0;
    assert_eq!(
        iss_execs as usize, kernel_steps,
        "a cold analytic batch must cost one ISS execution per unique kernel step, \
         not steps x batch"
    );
    assert_eq!(hits as usize, kernel_steps * (batch - 1), "every replay is a cache hit");

    // Warm batch: zero ISS executions, everything cache-served.
    let runs1 = stats.runs.load(Ordering::Relaxed);
    let again = run_plan_batch(&plan, &inputs, mac, ExecMode::Analytic, 4).unwrap();
    assert_eq!(again.len(), batch);
    assert_eq!(
        stats.runs.load(Ordering::Relaxed) - runs1,
        0,
        "a warm analytic batch must not touch the ISS at all"
    );
    assert_eq!(
        (stats.analytic_hits.load(Ordering::Relaxed) - hits0) as usize,
        kernel_steps * (2 * batch - 1)
    );

    // And the replays are the same numbers the cold batch reported.
    for (a, b) in runs.iter().zip(&again) {
        assert_eq!(a.logits, b.logits);
        assert_eq!(a.total_cycles(), b.total_cycles());
    }
}
