//! Bench: Table 4 — times the energy-model evaluation over measured
//! cycle counts (the platform model itself is trivially fast; the bench
//! covers the full path including one ISS layer measurement).

use mpnn::bench::bench;
use mpnn::dse::cycles::measure_layer;
use mpnn::energy::{ASIC_BASELINE, ASIC_MODIFIED, FPGA_BASELINE, FPGA_MODIFIED};
use mpnn::exp::ExpOpts;
use mpnn::sim::MacUnitConfig;

fn main() {
    let opts = ExpOpts::default();
    let model = opts.load_model("lenet5").unwrap();
    let a = mpnn::models::analyze(&model.spec);
    bench("table4/lenet-layer+energy-model", 5, || {
        let base = measure_layer(&a.layers[1], None, MacUnitConfig::full(), 1).unwrap();
        let fast = measure_layer(
            &a.layers[1],
            Some(mpnn::isa::MacMode::W4),
            MacUnitConfig::full(),
            1,
        )
        .unwrap();
        let rb = ASIC_BASELINE.evaluate(base.macs, base.cycles);
        let rm = ASIC_MODIFIED.evaluate(fast.macs, fast.cycles);
        assert!(rm.gops_per_w > rb.gops_per_w);
        let fb = FPGA_BASELINE.evaluate(base.macs, base.cycles);
        let fm = FPGA_MODIFIED.evaluate(fast.macs, fast.cycles);
        assert!(fm.gops_per_w > fb.gops_per_w);
    });
}
