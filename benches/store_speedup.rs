//! Bench: content-addressed result store — warm (store-served) vs cold
//! (backend-run) sweep evaluation, the §Perf metric of `--store`.
//!
//! Cold passes wipe the store and the RAM report cache before scoring a
//! small lenet5 configuration set, so every evaluation pays the host
//! backend and persists its report; warm passes run a fresh coordinator
//! over the populated store with the RAM cache cleared each iteration,
//! so every evaluation is a keyed file read. The ledger is asserted
//! deterministically before any timing claim: warm passes run the
//! backend **zero** times and miss the store **zero** times.
//!
//! `BENCH_ITERS` overrides the measured iteration count (CI smoke runs
//! set 2); `STORE_BENCH_ASSERT` gates the worst-case warm-vs-cold
//! speedup (a conservative floor — store reads beat host evaluation by
//! orders of magnitude, so a violation means the read path regressed,
//! not that the runner was noisy). Single-sample runs skip the floor: a
//! ratio of two single timings is meaningless. Results land in
//! `BENCH_store_speedup.json` with the hit/miss counters.

use mpnn::bench::{bench, iters_from_env, JsonReport};
use mpnn::coordinator::{Coordinator, HostEval};
use mpnn::models::format::load_or_fallback;
use mpnn::store::ResultStore;
use std::path::{Path, PathBuf};
use std::sync::atomic::Ordering;

fn env_floor(var: &str) -> Option<f64> {
    std::env::var(var).ok().and_then(|v| v.parse::<f64>().ok())
}

/// Host-evaluator coordinator over the synthetic lenet5 fallback,
/// attached to the shared bench store.
fn coordinator(seed: u64, store_dir: &Path) -> Coordinator {
    let model = load_or_fallback(Path::new("/nonexistent"), "lenet5", seed).unwrap();
    let test = model.test.clone();
    let mut c = Coordinator::new(model, Box::new(HostEval { test }), 2).unwrap();
    c.attach_store(ResultStore::open(store_dir).unwrap()).unwrap();
    c
}

fn main() {
    let iters = iters_from_env(3);
    let n_eval = 16usize;
    let mut report = JsonReport::new("store_speedup");

    let dir: PathBuf =
        std::env::temp_dir().join(format!("mpnn_store_bench_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);

    let cold_c = coordinator(0xD5E, &dir);
    let n = cold_c.analysis.layers.len();
    let mut configs = vec![vec![8u32; n], vec![4u32; n], vec![2u32; n]];
    let mut mixed = vec![4u32; n];
    mixed[0] = 8;
    configs.push(mixed);

    println!("result store: cold (backend runs + persists) vs warm (store-served) evaluation");
    println!(
        "  lenet5 (synthetic fallback), {} configs, {n_eval} images, host evaluator",
        configs.len()
    );

    let cold = bench("store/lenet5-4cfg/cold", iters, || {
        let _ = std::fs::remove_dir_all(&dir);
        cold_c.clear_report_cache();
        for cfg in &configs {
            cold_c.evaluate(cfg, n_eval).unwrap();
        }
    });
    // Every cold pass (warm-up + timed) must have run the backend for
    // every configuration — the store was wiped each time.
    let passes = (iters + 1) as u64;
    let cold_runs = cold_c.metrics.acc_evals.load(Ordering::Relaxed);
    assert_eq!(cold_runs, configs.len() as u64 * passes, "cold passes must run the backend");

    // The last cold pass left the store populated; a fresh coordinator
    // (empty RAM cache per iteration) measures the pure store path.
    let warm_c = coordinator(0xD5E, &dir);
    let warm = bench("store/lenet5-4cfg/warm", iters, || {
        warm_c.clear_report_cache();
        for cfg in &configs {
            warm_c.evaluate(cfg, n_eval).unwrap();
        }
    });
    assert_eq!(
        warm_c.metrics.acc_evals.load(Ordering::Relaxed),
        0,
        "warm passes must not run the backend"
    );
    let (hits, misses) = warm_c.store_counters().unwrap();
    assert_eq!(misses, 0, "warm passes must not miss the store");
    assert_eq!(hits, configs.len() as u64 * passes);

    let speedup = cold.median().as_secs_f64() / warm.median().as_secs_f64();
    println!(
        "  => warm store-served evaluation speedup: {speedup:.1}x \
         ({hits} store hits, {misses} misses, {cold_runs} cold backend runs)"
    );

    report.record(&cold, &[("configs", configs.len() as f64), ("n_eval", n_eval as f64)]);
    report.record(&warm, &[("store_hits", hits as f64), ("store_misses", misses as f64)]);
    report.summary("store_speedup_warm_vs_cold", speedup);
    report.summary("store_hits", hits as f64);
    report.summary("store_misses", misses as f64);
    report.summary("cold_backend_runs", cold_runs as f64);

    // Regression gate, opt-in via env (same contract as the other
    // benches: floors only apply with >= 2 iterations).
    if iters < 2 {
        println!("single-sample run: regression floor not enforced");
    } else if let Some(min) = env_floor("STORE_BENCH_ASSERT") {
        assert!(
            speedup >= min,
            "store read-path regression: warm-vs-cold speedup {speedup:.2}x < {min}x"
        );
    }

    let path = report.write().expect("write bench json");
    println!("bench json: {}", path.display());
    let _ = std::fs::remove_dir_all(&dir);
}
