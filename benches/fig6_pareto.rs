//! Bench: Fig. 6 — times a reduced DSE sweep (LeNet5, exhaustive pruned
//! space, host accuracy path excluded: measures quantize+cycle+PJRT).

use mpnn::bench::bench;
use mpnn::exp::{fig6, ExpOpts};

fn main() {
    let opts = ExpOpts { budget: 27, eval_n: 64, ..Default::default() };
    bench("fig6/lenet5-sweep(27 cfgs, 64 imgs)", 2, || {
        fig6::sweep_model(&opts, "lenet5").unwrap();
    });
}
