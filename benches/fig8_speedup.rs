//! Bench: Fig. 8 — times sweep + threshold selection on the CIFAR CNN.

use mpnn::bench::bench;
use mpnn::exp::{fig6, fig8, ExpOpts};

fn main() {
    let opts = ExpOpts { budget: 27, eval_n: 64, ..Default::default() };
    bench("fig8/cifar-select(27 cfgs)", 2, || {
        let sweep = fig6::sweep_model(&opts, "cifar_cnn").unwrap();
        let sel = fig8::select(sweep);
        assert!(sel.selections.iter().any(|s| s.is_some()));
    });
}
