//! Bench: lazy config-space enumeration — streamed (index-decoded, one
//! config materialized at a time) vs materialized (`enumerate`, the
//! whole space collected) over the 3^13-configuration synthetic space
//! (1,594,323 configs), the §Perf metric of the streaming sweep stack.
//!
//! Both passes fold every decoded width into a checksum, asserted equal
//! across passes before any timing claim, so neither loop can be
//! optimized away and both demonstrably visit the identical sequence.
//! The headline numbers are configs/sec per pass plus the peak
//! alive-set size — 1 config for the streamed pass, the full space for
//! the materialized one; that gap, not the throughput, is what lets
//! guided sweeps run at 10^6+ configurations.
//!
//! `BENCH_ITERS` overrides the measured iteration count (CI smoke runs
//! set 2); `SPACE_BENCH_ASSERT` gates the minimum streamed-pass
//! throughput in configs/sec (a conservative floor — decode is a few
//! dozen integer ops, so a violation means the decode path regressed).
//! Single-sample runs skip the floor. Results land in
//! `BENCH_space_streaming.json`.

use mpnn::bench::{bench, iters_from_env, JsonReport};
use mpnn::dse::{default_pinned, enumerate, ConfigSpace};

fn env_floor(var: &str) -> Option<f64> {
    std::env::var(var).ok().and_then(|v| v.parse::<f64>().ok())
}

/// Fold a config's widths into a running FNV-style checksum — cheap
/// enough not to dominate the decode, strong enough that a drifted
/// sequence cannot collide by accident.
fn fold(mut acc: u64, cfg: &[u32]) -> u64 {
    for &b in cfg {
        acc = (acc ^ b as u64).wrapping_mul(0x1000_0000_01b3);
    }
    acc
}

fn main() {
    let iters = iters_from_env(3);
    let free = 13u32; // 3^13 = 1,594,323 configs, past the 10^6 mark
    let n_layers = free as usize + 1; // layer 0 pinned at 8-bit
    let budget = 3usize.pow(free);
    let seed = 0u64;
    let space = ConfigSpace::new(n_layers, &default_pinned(), budget, seed);
    assert!(space.is_exhaustive(), "the bench space must be index-decoded");
    let total = space.len();
    let mut report = JsonReport::new("space_streaming");

    println!("config-space enumeration: streamed (lazy decode) vs materialized (full Vec)");
    println!("  {n_layers} layers, layer 0 pinned, 3^{free} = {total} configs");

    let mut streamed_sum = 0u64;
    let streamed = bench("space/3p13/streamed", iters, || {
        let mut acc = 0xcbf2_9ce4_8422_2325u64;
        for cfg in space.iter() {
            acc = fold(acc, &cfg);
        }
        streamed_sum = acc;
    });

    let mut materialized_sum = 0u64;
    let mut materialized_len = 0usize;
    let materialized = bench("space/3p13/materialized", iters, || {
        let all = enumerate(n_layers, &default_pinned(), budget, seed);
        let mut acc = 0xcbf2_9ce4_8422_2325u64;
        for cfg in &all {
            acc = fold(acc, cfg);
        }
        materialized_sum = acc;
        materialized_len = all.len();
    });

    // Identity before any timing claim: both passes visited the same
    // sequence, and the materialized pass really held the whole space.
    assert_eq!(streamed_sum, materialized_sum, "streamed sequence drifted from enumerate");
    assert_eq!(materialized_len, total);

    let streamed_cps = total as f64 / streamed.median().as_secs_f64();
    let materialized_cps = total as f64 / materialized.median().as_secs_f64();
    println!(
        "  => streamed {streamed_cps:.0} configs/sec (peak alive 1 config), \
         materialized {materialized_cps:.0} configs/sec (peak alive {total} configs)"
    );

    report.record(&streamed, &[("configs", total as f64), ("peak_alive", 1.0)]);
    report.record(&materialized, &[("configs", total as f64), ("peak_alive", total as f64)]);
    report.summary("configs", total as f64);
    report.summary("streamed_configs_per_sec", streamed_cps);
    report.summary("materialized_configs_per_sec", materialized_cps);
    report.summary("peak_alive_streamed", 1.0);
    report.summary("peak_alive_materialized", total as f64);

    // Regression gate, opt-in via env (same contract as the other
    // benches: floors only apply with >= 2 iterations).
    if iters < 2 {
        println!("single-sample run: regression floor not enforced");
    } else if let Some(min) = env_floor("SPACE_BENCH_ASSERT") {
        assert!(
            streamed_cps >= min,
            "streamed decode regression: {streamed_cps:.0} configs/sec < {min} floor"
        );
    }

    let path = report.write().expect("write bench json");
    println!("bench json: {}", path.display());
}
