//! Bench: Fig. 7 — times the per-Mode ablation measurements (dense +
//! conv layer under the 3 datapath configurations × 3 widths).

use mpnn::bench::bench;
use mpnn::exp::{fig7, ExpOpts};

fn main() {
    let opts = ExpOpts::default();
    bench("fig7/mode-ablations(dense+conv)", 3, || {
        fig7::run(&opts).unwrap();
    });
}
