//! Bench: Table 3 — times one baseline-ISS measurement pass per model
//! and regenerates the Table-3 rows.

use mpnn::bench::bench;
use mpnn::exp::{table3, ExpOpts};

fn main() {
    let opts = ExpOpts::default();
    bench("table3/baseline-cycles(all models)", 3, || {
        table3::run(&opts).unwrap();
    });
}
