//! Bench: raw ISS throughput (simulated instructions per host second) —
//! the §Perf hot-path metric for the L3 simulator. Uses the CIFAR CNN's
//! second conv layer as a representative kernel workload.

use mpnn::bench::bench_val;
use mpnn::dse::cycles::measure_layer;
use mpnn::exp::ExpOpts;
use mpnn::isa::MacMode;
use mpnn::sim::MacUnitConfig;
use std::time::Instant;

fn main() {
    let opts = ExpOpts::default();
    let model = opts.load_model("cifar_cnn").unwrap();
    let a = mpnn::models::analyze(&model.spec);
    let conv = a.layers[1];

    for (label, mode) in
        [("baseline", None), ("mode1-w8", Some(MacMode::W8)), ("mode3-w2", Some(MacMode::W2))]
    {
        let t0 = Instant::now();
        let (stats, cost) = bench_val(&format!("iss/{label}-conv-layer"), 3, || {
            measure_layer(&conv, mode, MacUnitConfig::full(), 7)
        });
        let _ = t0;
        let mips = cost.instret as f64 / stats.median().as_secs_f64() / 1e6;
        println!(
            "  -> {:.1}M instructions, {:.0} M simulated-instr/s (median)",
            cost.instret as f64 / 1e6,
            mips
        );
    }
}
