//! Bench: raw ISS throughput (simulated instructions per host second) —
//! the §Perf hot-path metric for the L3 simulator.
//!
//! Two comparisons land in the bench trajectory (human output + the
//! machine-readable `BENCH_iss_throughput.json`):
//!
//! 1. **legacy `step()` interpreter vs the micro-op engine** on the
//!    CIFAR CNN's second conv layer (the original acceptance metric),
//! 2. **engine fusion generations**: the PR-1 engine (strip/MAC/latch
//!    fusion only, `TranslateOpts::v1`) vs the current engine with the
//!    requant-epilogue superinstruction and counted-loop strip
//!    execution, across dense/conv kernel families. Timing is
//!    value-independent, so the kernels run over zeroed operand
//!    buffers through the pooled session,
//! 3. **analytic fast path vs full ISS** on a warm 16-input lenet5
//!    batch — the `ExecMode::Analytic` replay speedup, landed in its
//!    own `BENCH_analytic_speedup.json` trajectory after a bit-identity
//!    check of logits and counters.
//!
//! `BENCH_ITERS` overrides the measured iteration count (CI smoke runs
//! set 2); `ISS_BENCH_ASSERT` / `ISS_FUSION_ASSERT` /
//! `ANALYTIC_BENCH_ASSERT` gate the worst-case speedups (floors well
//! below target so shared-runner noise can't flake CI, while a true
//! regression still fails) — the floors are skipped on single-sample
//! runs, where a ratio of two single timings is meaningless.

use mpnn::bench::{bench_val, iters_from_env, JsonReport};
use mpnn::dse::cycles::measure_layer_backend;
use mpnn::exp::ExpOpts;
use mpnn::isa::MacMode;
use mpnn::kernels::conv::ConvSpec;
use mpnn::kernels::dense::DenseSpec;
use mpnn::kernels::run::ExecBackend;
use mpnn::kernels::{conv, dense, KernelProgram, DATA_BASE, PROG_BASE};
use mpnn::nn::quant::Requant;
use mpnn::sim::session::{CompiledImage, SimSession};
use mpnn::sim::{CoreConfig, ExitReason, MacUnitConfig, Timing, TranslateOpts};

fn env_floor(var: &str) -> Option<f64> {
    std::env::var(var).ok().and_then(|v| v.parse::<f64>().ok())
}

fn main() {
    let iters = iters_from_env(3);
    let mut report = JsonReport::new("iss_throughput");

    // ---- Part 1: legacy step() interpreter vs the engine ---------------
    let opts = ExpOpts::default();
    let model = opts.load_model("cifar_cnn").unwrap();
    let a = mpnn::models::analyze(&model.spec);
    let conv_layer = a.layers[1];

    println!("ISS throughput: legacy step() interpreter vs pre-decoded micro-op engine");
    let mut mode_worst = f64::INFINITY;
    for (label, mode) in
        [("baseline", None), ("mode1-w8", Some(MacMode::W8)), ("mode3-w2", Some(MacMode::W2))]
    {
        let mut mips = [0.0f64; 2];
        for (bi, backend) in [ExecBackend::Legacy, ExecBackend::Engine].into_iter().enumerate() {
            let tag = if bi == 0 { "legacy" } else { "engine" };
            let (stats, cost) = bench_val(&format!("iss/{label}-conv-layer/{tag}"), iters, || {
                measure_layer_backend(&conv_layer, mode, MacUnitConfig::full(), 7, backend)
                    .unwrap()
            });
            mips[bi] = cost.instret as f64 / stats.median().as_secs_f64() / 1e6;
            println!(
                "  -> {:.1}M instructions, {:.0} M simulated-instr/s (median, {tag})",
                cost.instret as f64 / 1e6,
                mips[bi]
            );
            report.record(&stats, &[("mips", mips[bi]), ("instret", cost.instret as f64)]);
        }
        let speedup = mips[1] / mips[0];
        if mode.is_some() {
            mode_worst = mode_worst.min(speedup);
        }
        println!("  => engine speedup on {label}: {speedup:.2}x");
        report.summary(&format!("legacy_speedup_{label}"), speedup);
    }
    report.summary("legacy_speedup_worst", mode_worst);

    // ---- Part 2: engine fusion generations (v1 vs current) -------------
    let rq = Requant::from_real_scale(0.004);
    let families: Vec<(&str, KernelProgram)> = vec![
        (
            "dense-mode2-looped",
            dense::build_mode(
                MacMode::W4,
                DenseSpec { in_dim: 2304, out_dim: 64, rq, relu: true, out_i32: false },
            ),
        ),
        (
            "dense-baseline",
            dense::build_baseline(DenseSpec {
                in_dim: 256,
                out_dim: 48,
                rq,
                relu: true,
                out_i32: false,
            }),
        ),
        (
            "conv-mode3",
            conv::build_mode(
                MacMode::W2,
                ConvSpec { h: 14, w: 14, cin: 16, cout: 12, k: 3, stride: 1, rq, relu: true },
            ),
        ),
        (
            "conv-baseline",
            conv::build_baseline(ConvSpec {
                h: 12,
                w: 12,
                cin: 8,
                cout: 8,
                k: 3,
                stride: 1,
                rq,
                relu: true,
            }),
        ),
    ];

    println!("engine fusion generations: v1 (PR-1 fusions) vs current (+requant, +counted loops)");
    let session = SimSession::global();
    let mut fusion_worst = f64::INFINITY;
    for (label, kp) in &families {
        let cfg = CoreConfig {
            mem_size: kp.mem_size.max(DATA_BASE + 4096) as usize,
            ..Default::default()
        };
        let mut mips = [0.0f64; 2];
        for (gi, topts) in [TranslateOpts::v1(), TranslateOpts::default()].into_iter().enumerate()
        {
            let tag = if gi == 0 { "engine-v1" } else { "engine" };
            let image =
                CompiledImage::new_with_opts(kp.prog.clone(), PROG_BASE, Timing::default(), topts);
            let (stats, perf) = bench_val(&format!("iss/{label}/{tag}"), iters, || {
                let (perf, reason) = session.execute(cfg, &image, |_| {}, |core| core.perf);
                assert_eq!(reason, ExitReason::Ecall, "{label}/{tag}");
                perf
            });
            mips[gi] = perf.instret as f64 / stats.median().as_secs_f64() / 1e6;
            println!(
                "  -> {label}/{tag}: {:.2}M instr, {:.0} M simulated-instr/s (median)",
                perf.instret as f64 / 1e6,
                mips[gi]
            );
            report.record(&stats, &[("mips", mips[gi]), ("instret", perf.instret as f64)]);
        }
        let speedup = mips[1] / mips[0];
        fusion_worst = fusion_worst.min(speedup);
        println!("  => requant+counted-loop fusion speedup on {label}: {speedup:.2}x");
        report.summary(&format!("fusion_speedup_{label}"), speedup);
    }
    report.summary("fusion_speedup_worst", fusion_worst);

    // Per-class hit counters: the new superinstruction classes must
    // actually fire on the kernel families (deterministic — not a
    // timing assertion).
    let hits = session.stats.engine.snapshot();
    println!(
        "engine hits: load_mac {} scalar_mac {} latch {} requant {} counted_loops {} \
         (iters {}) fallbacks {}",
        hits.load_mac,
        hits.scalar_mac,
        hits.latch,
        hits.requant,
        hits.counted_loops,
        hits.counted_iters,
        hits.fallbacks,
    );
    assert!(hits.requant > 0, "Requant superinstruction never fired");
    assert!(hits.counted_loops > 0, "counted-loop execution never fired");
    report.summary("hits_load_mac", hits.load_mac as f64);
    report.summary("hits_scalar_mac", hits.scalar_mac as f64);
    report.summary("hits_latch", hits.latch as f64);
    report.summary("hits_requant", hits.requant as f64);
    report.summary("hits_counted_loops", hits.counted_loops as f64);
    report.summary("hits_counted_iters", hits.counted_iters as f64);
    report.summary("engine_fallbacks", hits.fallbacks as f64);

    // ---- Part 3: execution-plan cache on a 2-input batch ---------------
    // Deterministic, not a timing assertion: a configuration must lower
    // into its ExecutionPlan exactly once and the batch must replay it
    // (>= 1 cache hit) — the compile-once / run-many contract of
    // models::plan::plan_for.
    {
        use mpnn::models::infer::{quantize_input, quantize_model};
        use mpnn::models::sim_exec::{modes_for, run_model_batch};
        use std::sync::atomic::Ordering;

        let stats = &session.stats;
        let compiles0 = stats.plan_compiles.load(Ordering::Relaxed);
        let hits0 = stats.plan_hits.load(Ordering::Relaxed);

        let model = opts.load_model("lenet5").unwrap();
        let n = mpnn::models::analyze(&model.spec).layers.len();
        let qm = quantize_model(&model.spec, &model.params, &model.sites, &vec![4u32; n]);
        let inputs: Vec<_> =
            model.test.images[..2].iter().map(|im| quantize_input(&qm, im)).collect();
        // Two 2-input batches of the same configuration: the first
        // lowers the plan (one compile), the second resolves it from
        // the cache (a hit) — across both, exactly one plan exists.
        for round in 0..2 {
            let runs =
                run_model_batch(&qm, &inputs, &modes_for(&qm), MacUnitConfig::full(), 2).unwrap();
            assert_eq!(runs.len(), 2, "round {round}");
        }

        let compiles = stats.plan_compiles.load(Ordering::Relaxed) - compiles0;
        let hits = stats.plan_hits.load(Ordering::Relaxed) - hits0;
        println!("plan cache across two 2-input run_model_batch calls: {compiles} compiled, {hits} hits");
        assert_eq!(compiles, 1, "one configuration must compile exactly one plan");
        assert!(hits >= 1, "a repeated batch must replay the compiled plan (hits {hits})");
        report.summary("plan_compiles_2input_batch", compiles as f64);
        report.summary("plan_hits_2input_batch", hits as f64);
    }

    // ---- Part 4: analytic fast path vs full ISS on a 16-input batch ----
    // The §Perf metric of the analytic backend: once the session cost
    // cache knows every kernel step of a configuration, a batch replays
    // as host kernels with cache-served counters — the ISS runs zero
    // times. Results are bit-compared against the full ISS batch before
    // any timing claim, and land in their own trajectory file
    // (`BENCH_analytic_speedup.json`).
    let analytic_speedup = {
        use mpnn::models::infer::{quantize_input, quantize_model};
        use mpnn::models::plan::plan_for;
        use mpnn::models::sim_exec::{modes_for, run_plan_batch, ExecMode};
        use std::sync::atomic::Ordering;

        let mut areport = JsonReport::new("analytic_speedup");
        let model = opts.load_model("lenet5").unwrap();
        let n = mpnn::models::analyze(&model.spec).layers.len();
        let qm = quantize_model(&model.spec, &model.params, &model.sites, &vec![4u32; n]);
        let inputs: Vec<_> =
            model.test.images[..16].iter().map(|im| quantize_input(&qm, im)).collect();
        let plan = plan_for(&qm, &modes_for(&qm)).unwrap();
        let mac = MacUnitConfig::full();

        // Warm the cost cache outside the timed region: the comparison
        // is full-ISS batch vs the analytic steady state a sweep sits
        // in, not vs the one-off cold measurement pass.
        run_plan_batch(&plan, &inputs[..1], mac, ExecMode::Analytic, 1).unwrap();

        println!("analytic fast path vs full ISS: lenet5 4-bit, 16-input batch, 4 workers");
        let (iss_stats, iss_runs) = bench_val("iss/lenet5-batch16/iss", iters, || {
            run_plan_batch(&plan, &inputs, mac, ExecMode::Iss, 4).unwrap()
        });
        let (an_stats, an_runs) = bench_val("iss/lenet5-batch16/analytic", iters, || {
            run_plan_batch(&plan, &inputs, mac, ExecMode::Analytic, 4).unwrap()
        });
        // Bit-identity sanity before any timing claim.
        assert_eq!(iss_runs.len(), an_runs.len());
        for (a, b) in iss_runs.iter().zip(&an_runs) {
            assert_eq!(a.logits, b.logits, "analytic logits must match the ISS");
            assert_eq!(a.total_cycles(), b.total_cycles(), "analytic counters must match the ISS");
        }
        let speedup = iss_stats.median().as_secs_f64() / an_stats.median().as_secs_f64();
        let hits = session.stats.analytic_hits.load(Ordering::Relaxed);
        println!(
            "  => analytic replay speedup on the 16-input batch: {speedup:.1}x \
             (analytic cost-cache hits so far: {hits})"
        );
        areport.record(&iss_stats, &[("batch", 16.0)]);
        areport.record(&an_stats, &[("batch", 16.0)]);
        areport.summary("analytic_speedup_batch16", speedup);
        areport.summary("analytic_hits", hits as f64);
        let apath = areport.write().expect("write bench json");
        println!("bench json: {}", apath.display());
        speedup
    };

    println!(
        "iss_throughput: worst engine-vs-legacy {mode_worst:.2}x (target >= 2x), \
         worst fusion-generation {fusion_worst:.2}x (target >= 1.5x), \
         analytic batch replay {analytic_speedup:.1}x (target >= 5x)"
    );

    // Regression gates, opt-in via env (CI uses conservative floors).
    // A single-sample run (BENCH_ITERS=1 smoke) cannot support a ratio
    // assertion — one scheduler stall on either side of the quotient
    // would flake it — so the floors only apply with >= 2 iterations;
    // the uploaded JSON carries the trajectory either way.
    if iters < 2 {
        println!("single-sample run: regression floors not enforced");
    } else {
        if let Some(min) = env_floor("ISS_BENCH_ASSERT") {
            assert!(
                mode_worst >= min,
                "engine regression: worst mode-kernel speedup {mode_worst:.2}x < {min}x"
            );
        }
        if let Some(min) = env_floor("ISS_FUSION_ASSERT") {
            assert!(
                fusion_worst >= min,
                "fusion regression: worst generation speedup {fusion_worst:.2}x < {min}x"
            );
        }
        if let Some(min) = env_floor("ANALYTIC_BENCH_ASSERT") {
            assert!(
                analytic_speedup >= min,
                "analytic fast-path regression: 16-input batch speedup \
                 {analytic_speedup:.2}x < {min}x"
            );
        }
    }

    let path = report.write().expect("write bench json");
    println!("bench json: {}", path.display());
}
