//! Bench: raw ISS throughput (simulated instructions per host second) —
//! the §Perf hot-path metric for the L3 simulator. Uses the CIFAR CNN's
//! second conv layer as a representative kernel workload and reports
//! the legacy `step()` interpreter next to the pre-decoded micro-op
//! engine so the engine speedup lands in the bench trajectory.

use mpnn::bench::bench_val;
use mpnn::dse::cycles::measure_layer_backend;
use mpnn::exp::ExpOpts;
use mpnn::isa::MacMode;
use mpnn::kernels::run::ExecBackend;
use mpnn::sim::MacUnitConfig;

fn main() {
    let opts = ExpOpts::default();
    let model = opts.load_model("cifar_cnn").unwrap();
    let a = mpnn::models::analyze(&model.spec);
    let conv = a.layers[1];

    println!("ISS throughput: legacy step() interpreter vs pre-decoded micro-op engine");
    let mut mode_worst = f64::INFINITY;
    for (label, mode) in
        [("baseline", None), ("mode1-w8", Some(MacMode::W8)), ("mode3-w2", Some(MacMode::W2))]
    {
        let mut mips = [0.0f64; 2];
        for (bi, backend) in [ExecBackend::Legacy, ExecBackend::Engine].into_iter().enumerate() {
            let tag = if bi == 0 { "legacy" } else { "engine" };
            let (stats, cost) = bench_val(&format!("iss/{label}-conv-layer/{tag}"), 3, || {
                measure_layer_backend(&conv, mode, MacUnitConfig::full(), 7, backend).unwrap()
            });
            mips[bi] = cost.instret as f64 / stats.median().as_secs_f64() / 1e6;
            println!(
                "  -> {:.1}M instructions, {:.0} M simulated-instr/s (median, {tag})",
                cost.instret as f64 / 1e6,
                mips[bi]
            );
        }
        let speedup = mips[1] / mips[0];
        if mode.is_some() {
            mode_worst = mode_worst.min(speedup);
        }
        println!("  => engine speedup on {label}: {speedup:.2}x");
    }
    println!(
        "iss_throughput: worst mode-kernel engine-vs-legacy speedup {mode_worst:.2}x \
         (acceptance target: >= 2x)"
    );
    // Regression gate, opt-in: ISS_BENCH_ASSERT holds the minimum
    // acceptable speedup. CI uses a floor well below the 2x target so
    // shared-runner noise can't flip a healthy engine red, while a
    // true regression (engine ~1x or slower) still fails.
    if let Some(min) = std::env::var("ISS_BENCH_ASSERT").ok().and_then(|v| v.parse::<f64>().ok())
    {
        assert!(
            mode_worst >= min,
            "engine regression: worst mode-kernel speedup {mode_worst:.2}x < {min}x"
        );
    }
}
