//! Bench: Fig. 4 — times the MobileNetV1 per-layer memory-access
//! measurement (full cycle-model build: 28 layers × 4 kernel variants).

use mpnn::bench::bench;
use mpnn::exp::{fig4, ExpOpts};

fn main() {
    let opts = ExpOpts::default();
    bench("fig4/mobilenet-mem-reduction", 2, || {
        fig4::run(&opts).unwrap();
    });
}
