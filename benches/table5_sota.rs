//! Bench: Table 5 — regenerates the SOTA comparison table (literature
//! constants + our computed row at a representative operating point).

use mpnn::bench::bench;
use mpnn::energy::sota::{competitors, ours};
use mpnn::energy::ASIC_MODIFIED;

fn main() {
    bench("table5/sota-table", 10, || {
        let r_lo = ASIC_MODIFIED.evaluate(2_800_000, 3_000_000);
        let r_hi = ASIC_MODIFIED.evaluate(2_800_000, 2_000_000);
        let mut t = competitors();
        t.push(ours(r_lo.gops, r_hi.gops, r_lo.gops_per_w, r_hi.gops_per_w));
        assert_eq!(t.len(), 7);
    });
}
