"""Quantization arithmetic — the Python mirror of ``rust/src/nn/quant.rs``.

Every function here is specified to be *bit-exact* against its Rust twin;
the contract is enforced by exported test vectors (``tests/test_quantize.py``
regenerates the vectors the Rust integration tests consume).

Scheme (identical to the Rust side):

* symmetric per-tensor quantization, zero point 0,
* weight grids: int8 / int4 / int2 (the paper's 8/4/2-bit precisions),
* int32 accumulation, Jacob-style fixed-point requantization
  (Q31 multiplier + rounding right shift).
"""

from __future__ import annotations

import dataclasses

import numpy as np


def qrange(bits: int) -> tuple[int, int]:
    """Signed range of a ``bits``-wide weight grid."""
    return -(1 << (bits - 1)), (1 << (bits - 1)) - 1


def symmetric_scale(abs_max: float, bits: int) -> float:
    """Symmetric scale using the full negative range (Rust twin)."""
    qmax = float(1 << (bits - 1))
    return abs_max / qmax if abs_max > 0.0 else 1.0


def round_half_away(x: np.ndarray) -> np.ndarray:
    """Round half away from zero — matches Rust ``f32::round`` (NOT
    numpy's banker's rounding)."""
    return np.where(x >= 0, np.floor(x + 0.5), np.ceil(x - 0.5))


# Candidate scale multipliers for the MSE search (Rust twin order).
SCALE_CANDIDATES = [1.0, 0.9, 0.8, 0.7, 0.6, 1.15]


def _quantize_at(w, s, bits):
    lo, hi = qrange(bits)
    q = round_half_away((w / np.float32(s)).astype(np.float32))
    return np.clip(q, lo, hi).astype(np.int8)


def quantize_tensor(w: np.ndarray, bits: int) -> tuple[np.ndarray, float]:
    """Quantize a float tensor to the ``bits`` grid with an MSE-optimal
    scale chosen over a small candidate set (Rust twin); returns
    (int8 values on the grid, scale)."""
    w = np.asarray(w, dtype=np.float32)
    abs_max = float(np.abs(w).max()) if w.size else 0.0
    base = symmetric_scale(abs_max, bits)
    best_s, best_mse = base, np.inf
    for mult in SCALE_CANDIDATES:
        s = np.float32(base * mult)
        q = _quantize_at(w, s, bits)
        mse = float(((w - q.astype(np.float32) * s) ** 2).sum())
        if mse < best_mse:
            best_mse, best_s = mse, float(s)
    return _quantize_at(w, np.float32(best_s), bits), best_s


@dataclasses.dataclass(frozen=True)
class Requant:
    """Fixed-point requantization parameters: scale ≈ m / 2^31 / 2^shift."""

    m: int
    shift: int

    @staticmethod
    def from_real_scale(real_scale: float) -> "Requant":
        assert real_scale > 0.0, "requant scale must be positive"
        shift = 0
        s = float(real_scale)
        while s < 0.5:
            s *= 2.0
            shift += 1
        while s >= 1.0:  # scales >= 1 -> negative (left) shift
            s /= 2.0
            shift -= 1
        m = int(round(s * (1 << 31)))
        if m == 1 << 31:
            m //= 2
            shift -= 1
        return Requant(m=m, shift=shift)

    def real_scale(self) -> float:
        return self.m / float(1 << 31) / (2.0 ** self.shift)


def srdhm(a: np.ndarray, b: int) -> np.ndarray:
    """Saturating rounding doubling high multiply (vectorised over `a`)."""
    p = a.astype(np.int64) * np.int64(b)
    return ((p + (1 << 30)) >> 31).astype(np.int32)


def rounding_rshift(x: np.ndarray, n: int) -> np.ndarray:
    """Rounding arithmetic right shift (round half up); negative `n`
    shifts left (saturating int64, matching the Rust twin)."""
    if n == 0:
        return x.astype(np.int32)
    if n < 0:
        v = np.clip(x.astype(np.int64) << (-n), -(1 << 31), (1 << 31) - 1)
        return v.astype(np.int32)
    return ((x.astype(np.int64) + (1 << (n - 1))) >> n).astype(np.int32)


def requantize(acc: np.ndarray, rq: Requant, relu: bool) -> np.ndarray:
    """int32 accumulator → int8 output, optional fused ReLU."""
    r = rounding_rshift(srdhm(np.asarray(acc, dtype=np.int32), rq.m), rq.shift)
    lo = 0 if relu else -128
    return np.clip(r, lo, 127).astype(np.int8)


def quantize_layer(
    wf: np.ndarray,
    bf: np.ndarray,
    s_in: float,
    s_out: float,
    w_bits: int,
) -> tuple[np.ndarray, np.ndarray, Requant, float]:
    """Quantize one layer (Rust ``nn::quantize_layer`` twin).

    Returns (grid weights int8, int32 bias, requant, weight scale).
    """
    qw, s_w = quantize_tensor(wf, w_bits)
    # f32 intermediate like Rust: b / (s_in * s_w) with f32 rounding.
    denom = np.float32(s_in) * np.float32(s_w)
    bias = round_half_away((np.asarray(bf, np.float32) / denom).astype(np.float32)).astype(
        np.int64
    )
    rq = Requant.from_real_scale(float(s_in) * float(s_w) / float(s_out))
    return qw, bias.astype(np.int32), rq, s_w


# ---------------------------------------------------------------- packing ---


def weights_per_word(bits: int) -> int:
    return 32 // bits


def pack_weight_stream(w: np.ndarray, bits: int) -> np.ndarray:
    """Pack int-grid weights into little-endian-lane uint32 words,
    zero-padding the tail (Rust ``isa::custom::pack_weight_stream`` twin)."""
    w = np.asarray(w, dtype=np.int64)
    lo, hi = qrange(bits)
    assert w.min(initial=0) >= lo and w.max(initial=0) <= hi, "weights off grid"
    n = weights_per_word(bits)
    pad = (-len(w)) % n
    w = np.concatenate([w, np.zeros(pad, dtype=np.int64)])
    lanes = w.reshape(-1, n)
    mask = (1 << bits) - 1
    words = np.zeros(len(lanes), dtype=np.uint64)
    for i in range(n):
        words |= (lanes[:, i].astype(np.uint64) & mask) << (i * bits)
    return words.astype(np.uint32)


def unpack_weights(words: np.ndarray, bits: int) -> np.ndarray:
    """Inverse of :func:`pack_weight_stream` (sign-extended)."""
    n = weights_per_word(bits)
    words = np.asarray(words, dtype=np.uint64)
    lanes = np.zeros((len(words), n), dtype=np.int64)
    mask = (1 << bits) - 1
    half = 1 << (bits - 1)
    for i in range(n):
        field = (words >> (i * bits)) & mask
        lanes[:, i] = ((field + half) & mask) - half
    return lanes.reshape(-1).astype(np.int8)


def pack_dense(qw: np.ndarray, o: int, i: int, bits: int) -> np.ndarray:
    """Per-output-row packing (Rust ``nn::pack::pack_dense`` twin):
    row `r` occupies ``ceil(i / lanes)`` words."""
    qw = np.asarray(qw).reshape(o, i)
    return np.stack([pack_weight_stream(row, bits) for row in qw])
