"""Layer-1 Pallas kernels: the paper's packed mixed-precision MAC.

Two kernels reproduce the hardware contribution at kernel level:

* :func:`packed_gemm` — the general packed GEMM. Weights arrive packed
  4/8/16-per-uint32 exactly as the RISC-V ``nn_mac_<x>b`` instructions
  consume them; the kernel unpacks in VMEM (shift/mask vector ops),
  runs the int32 MAC reduction and fuses the Jacob-style requantization.
  The HBM→VMEM weight stream is 4/8/16× smaller than an unpacked int8
  GEMM — the Fig.-4 memory-traffic reduction expressed as bytes/tile.

* :func:`soft_simd_gemm_2b` — Mode-3's guard-bit soft SIMD (paper
  Eq. 2) demonstrated literally: each multiplier-equivalent lane performs
  ONE multiply ``A·(W_hi·2¹¹ + W_lo)`` whose fields are extracted into
  two products for two output channels sharing the activation, exactly
  like the 17-bit multiplier in the modified Ibex ALU.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the 32-bit packed
register becomes an int32 VMEM lane; the four 17-bit multipliers become
the VPU; `BlockSpec` plays the role of the paper's load/store
minimisation schedule. Kernels run with ``interpret=True`` — real-TPU
lowering emits Mosaic custom-calls the CPU PJRT plugin cannot execute.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import SOFT_SIMD_SHIFT

# Tile sizes: one weight tile must stay comfortably inside a ~16 MiB VMEM
# budget together with the activation tile (see DESIGN.md §Perf).
TILE_M = 128
TILE_O = 32


def _unpack_block(words, bits):
    """Unpack a [TO, W] uint32 block to [TO, W·lanes] int32 (VPU ops)."""
    lanes = 32 // bits
    mask = (1 << bits) - 1
    half = 1 << (bits - 1)
    shifts = jnp.arange(lanes, dtype=jnp.uint32) * jnp.uint32(bits)
    fields = (words[..., None] >> shifts).astype(jnp.int32) & mask
    signed = ((fields + half) & mask) - half
    return signed.reshape(words.shape[0], words.shape[1] * lanes)


def _requant_block(acc, m, shift, relu):
    """Fused requantization on an int32 block (bit-exact vs ref;
    negative shift = saturating left shift)."""
    p = acc.astype(jnp.int64) * m.astype(jnp.int64)
    r = ((p + (1 << 30)) >> 31).astype(jnp.int64)
    n = shift.astype(jnp.int64)
    pos = jnp.maximum(n, 0)
    nudge = jnp.where(n > 0, jnp.int64(1) << jnp.maximum(n - 1, 0), 0)
    right = (r + nudge) >> pos
    left = jnp.clip(r << jnp.maximum(-n, 0), -(2**31), 2**31 - 1)
    r = jnp.where(n >= 0, right, left).astype(jnp.int32)
    lo = 0 if relu else -128
    return jnp.clip(r, lo, 127).astype(jnp.int8)


def _gemm_kernel(acts_ref, w_ref, bias_ref, m_ref, shift_ref, out_ref, *, bits, relu, out_i32):
    acts = acts_ref[...].astype(jnp.int32)  # [TM, I]
    w = _unpack_block(w_ref[...], bits)  # [TO, I]
    acc = jax.lax.dot_general(
        acts,
        w,
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32,
    ) + bias_ref[...][None, :].astype(jnp.int32)
    if out_i32:
        out_ref[...] = acc
    else:
        out_ref[...] = _requant_block(acc, m_ref[0], shift_ref[0], relu)


def _pad_to(x, axis, mult):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("bits", "relu", "out_i32"))
def packed_gemm(acts, w_packed, bias, m, shift, *, bits, relu=False, out_i32=False):
    """Packed-weight GEMM via the Pallas kernel.

    acts: [M, I] int8 (I must be a lane multiple — pad with zeros, the
    packed weights are zero-padded to match, exactly like the RV32
    kernels' slack reads). w_packed: [O, I·bits/32] uint32. bias: [O]
    int32. m/shift: scalar int32 requant parameters.
    Returns [M, O] int8 (or int32 when ``out_i32``).
    """
    mdim, idim = acts.shape
    odim, wpg = w_packed.shape
    lanes = 32 // bits
    assert idim == wpg * lanes, f"acts I={idim} vs packed {wpg}·{lanes}"
    acts_p = _pad_to(acts, 0, TILE_M)
    w_p = _pad_to(w_packed, 0, TILE_O)
    bias_p = _pad_to(bias, 0, TILE_O)
    mp, op = acts_p.shape[0], w_p.shape[0]
    out = pl.pallas_call(
        functools.partial(_gemm_kernel, bits=bits, relu=relu, out_i32=out_i32),
        grid=(mp // TILE_M, op // TILE_O),
        in_specs=[
            pl.BlockSpec((TILE_M, idim), lambda i, j: (i, 0)),
            pl.BlockSpec((TILE_O, wpg), lambda i, j: (j, 0)),
            pl.BlockSpec((TILE_O,), lambda i, j: (j,)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((TILE_M, TILE_O), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, op), jnp.int32 if out_i32 else jnp.int8),
        interpret=True,
    )(acts_p, w_p, bias_p, m.reshape(1), shift.reshape(1))
    return out[:mdim, :odim]


def _soft_simd_kernel(acts_ref, weven_ref, wodd_ref, bias_ref, m_ref, shift_ref, out_ref, *, relu):
    """Mode-3 soft-SIMD GEMM tile: one composed multiply yields products
    for TWO output channels (paper Eq. 2 / Fig. 3c)."""
    acts = acts_ref[...].astype(jnp.int32)  # [TM, I]
    w_even = weven_ref[...].astype(jnp.int32)  # [TOP, I] int2 grid
    w_odd = wodd_ref[...].astype(jnp.int32)
    # Compose: the single 17-bit-multiplier operand per (channel-pair, i).
    composed = (w_odd << SOFT_SIMD_SHIFT) + w_even  # [TOP, I]
    # ONE multiplication per (m, pair, i) — the hardware's single MUL.
    p = acts[:, None, :] * composed[None, :, :]  # [TM, TOP, I]
    lo = (p << (32 - SOFT_SIMD_SHIFT)) >> (32 - SOFT_SIMD_SHIFT)
    hi = (p - lo) >> SOFT_SIMD_SHIFT
    acc_even = lo.sum(axis=2, dtype=jnp.int32)  # [TM, TOP]
    acc_odd = hi.sum(axis=2, dtype=jnp.int32)
    acc = jnp.stack([acc_even, acc_odd], axis=2).reshape(acts.shape[0], -1)
    acc = acc + bias_ref[...][None, :].astype(jnp.int32)
    out_ref[...] = _requant_block(acc, m_ref[0], shift_ref[0], relu)


@functools.partial(jax.jit, static_argnames=("relu",))
def soft_simd_gemm_2b(acts, w2, bias, m, shift, *, relu=False):
    """Mode-3 GEMM where every multiply covers two output channels via
    the Eq. (2) guard-bit composition. ``w2``: [O, I] int8 values on the
    int2 grid, O even. Bit-exact vs :func:`ref.packed_gemm_ref` at
    ``bits=2`` (same math, different factorisation — that is the point).
    """
    mdim, idim = acts.shape
    odim = w2.shape[0]
    assert odim % 2 == 0, "pad O to even"
    tile_pairs = TILE_O // 2
    w_even = w2[0::2]  # [O/2, I]
    w_odd = w2[1::2]
    acts_p = _pad_to(acts, 0, TILE_M)
    w_even = _pad_to(w_even, 0, tile_pairs)
    w_odd = _pad_to(w_odd, 0, tile_pairs)
    bias_p = _pad_to(bias, 0, TILE_O)
    mp, pairs_p = acts_p.shape[0], w_even.shape[0]
    out = pl.pallas_call(
        functools.partial(_soft_simd_kernel, relu=relu),
        grid=(mp // TILE_M, pairs_p // tile_pairs),
        in_specs=[
            pl.BlockSpec((TILE_M, idim), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_pairs, idim), lambda i, j: (j, 0)),
            pl.BlockSpec((tile_pairs, idim), lambda i, j: (j, 0)),
            pl.BlockSpec((TILE_O,), lambda i, j: (j,)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((TILE_M, TILE_O), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, pairs_p * 2), jnp.int8),
        interpret=True,
    )(acts_p, w_even, w_odd, bias_p, m.reshape(1), shift.reshape(1))
    return out[:mdim, :odim]


def vmem_bytes_estimate(bits: int, idim: int) -> dict:
    """Static VMEM footprint of one grid step (DESIGN.md §Perf).

    Two compression views: vs an int8 weight stream (8/bits = 1/2/4×,
    the HBM-bytes saving) and vs the baseline core's one-load-per-weight
    scheme (32/bits = 4/8/16×, the paper's memory-access saving).
    """
    act_tile = TILE_M * idim
    w_tile_packed = TILE_O * (idim * bits // 32) * 4  # = TO·I·bits/8
    w_tile_int8 = TILE_O * idim
    w_loads_baseline = TILE_O * idim * 4  # lb per weight -> one 32-bit access each
    out_tile = TILE_M * TILE_O * 4
    return {
        "act_tile_bytes": act_tile,
        "w_tile_packed_bytes": w_tile_packed,
        "w_tile_int8_bytes": w_tile_int8,
        "weight_compression_vs_int8": w_tile_int8 / w_tile_packed,
        "weight_compression_vs_wordloads": w_loads_baseline / w_tile_packed,
        "out_tile_bytes": out_tile,
        "total_bytes": act_tile + w_tile_packed + w_tile_int8 + out_tile,
    }
