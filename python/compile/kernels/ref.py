"""Pure-jnp correctness oracle for the packed-MAC Pallas kernels.

Everything here is the *specification*: the Pallas kernels
(``packed_mac.py``) must match these functions bit-exactly on every
shape/width (enforced by hypothesis sweeps in ``tests/test_kernel.py``),
and these functions in turn mirror the Rust host reference
(``rust/src/nn``) via exported cross-check vectors.
"""

from __future__ import annotations

import jax.numpy as jnp

# Q31 rounding nudge of the SRDHM (shared constant).
SRDHM_NUDGE = 1 << 30

# Guard-bit field offset of the paper's Eq. (2) soft-SIMD composition.
SOFT_SIMD_SHIFT = 11


def unpack_weights_jnp(words: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Unpack little-endian-lane packed weights: ``[..., W] uint32 →
    [..., W·(32/bits)] int32`` (sign-extended)."""
    lanes = 32 // bits
    mask = (1 << bits) - 1
    half = 1 << (bits - 1)
    shifts = jnp.arange(lanes, dtype=jnp.uint32) * jnp.uint32(bits)
    fields = (words[..., None] >> shifts).astype(jnp.int32) & mask
    signed = ((fields + half) & mask) - half
    return signed.reshape(*words.shape[:-1], words.shape[-1] * lanes)


def pack_weights_jnp(w: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Pack grid weights ``[..., N] int → [..., N/(32/bits)] uint32``
    (N must be a lane multiple; zero-pad first)."""
    lanes = 32 // bits
    mask = (1 << bits) - 1
    assert w.shape[-1] % lanes == 0, "pad to a lane multiple before packing"
    lanes_v = w.reshape(*w.shape[:-1], -1, lanes).astype(jnp.uint32) & jnp.uint32(mask)
    shifts = jnp.arange(lanes, dtype=jnp.uint32) * jnp.uint32(bits)
    return (lanes_v << shifts).sum(axis=-1, dtype=jnp.uint32)


def srdhm_jnp(a: jnp.ndarray, m) -> jnp.ndarray:
    """Saturating rounding doubling high multiply (int32 × int32) —
    bit-exact twin of ``nn::quant::srdhm``."""
    p = a.astype(jnp.int64) * jnp.asarray(m, jnp.int64)
    return ((p + SRDHM_NUDGE) >> 31).astype(jnp.int32)


def rounding_rshift_jnp(x: jnp.ndarray, n) -> jnp.ndarray:
    """Rounding arithmetic right shift with a traced shift amount;
    negative = saturating left shift (Rust twin)."""
    n = jnp.asarray(n, jnp.int64)
    pos = jnp.maximum(n, 0)
    nudge = jnp.where(n > 0, jnp.int64(1) << jnp.maximum(n - 1, 0), 0)
    right = (x.astype(jnp.int64) + nudge) >> pos
    left = jnp.clip(
        x.astype(jnp.int64) << jnp.maximum(-n, 0), -(2**31), 2**31 - 1
    )
    return jnp.where(n >= 0, right, left).astype(jnp.int32)


def requantize_jnp(acc: jnp.ndarray, m, shift, relu: bool) -> jnp.ndarray:
    """int32 accumulators → int8 (optional fused ReLU)."""
    r = rounding_rshift_jnp(srdhm_jnp(acc, m), shift)
    lo = 0 if relu else -128
    return jnp.clip(r, lo, 127).astype(jnp.int8)


def packed_gemm_ref(
    acts: jnp.ndarray,  # [M, I] int8 (I a lane multiple)
    w_packed: jnp.ndarray,  # [O, I/lanes] uint32
    bias: jnp.ndarray,  # [O] int32
    bits: int,
    m,  # scalar int32
    shift,  # scalar int32
    relu: bool,
    out_i32: bool,
):
    """Reference packed GEMM: unpack → int32 dot → bias → requantize.

    The oracle for the Pallas kernel and (transitively) for the RV32
    ``nn_mac`` kernels: ``acts @ unpack(w).T + bias``.
    """
    w = unpack_weights_jnp(w_packed, bits)  # [O, I]
    acc = acts.astype(jnp.int32) @ w.T.astype(jnp.int32) + bias[None, :].astype(jnp.int32)
    if out_i32:
        return acc
    return requantize_jnp(acc, m, shift, relu)


def soft_simd_compose_ref(w_even: jnp.ndarray, w_odd: jnp.ndarray) -> jnp.ndarray:
    """Eq. (2) weight composition: ``W_odd·2¹¹ + W_even`` (int2 grids)."""
    return (w_odd.astype(jnp.int32) << SOFT_SIMD_SHIFT) + w_even.astype(jnp.int32)


def soft_simd_dual_ref(a: jnp.ndarray, composed: jnp.ndarray):
    """Field extraction of the Eq. (2) dual product: recover
    ``(a·w_even, a·w_odd)`` from the single composed multiply."""
    p = a.astype(jnp.int32) * composed
    lo = (p << (32 - SOFT_SIMD_SHIFT)) >> (32 - SOFT_SIMD_SHIFT)
    hi = (p - lo) >> SOFT_SIMD_SHIFT
    return lo, hi
