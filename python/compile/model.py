"""Layer-2 JAX models: the Table-3 zoo in float (training) and integer
(inference-artifact) form.

The model specs mirror ``rust/src/models/zoo.rs`` structurally (node
lists, layer parameters, site walk) — the Rust artifact loader validates
the exported spec against its own zoo, so any drift fails loudly.

The integer forward (``build_qforward``) is the function AOT-lowered to
HLO text: all conv and dense MACs flow through the L1 Pallas packed-MAC
kernel (conv via im2col), depthwise uses patch-einsum with identical
integer arithmetic, and requantization follows the shared bit-exact
specification. Per-layer weights/biases/requant parameters are *traced
arguments*, so one HLO per model serves every mixed-precision DSE
configuration (bit-width only changes the weight values, which always
ride in int8 — a 2-bit-grid weight is still an int8 value).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.packed_mac import packed_gemm
from .kernels.ref import pack_weights_jnp, requantize_jnp, rounding_rshift_jnp, srdhm_jnp

# ------------------------------------------------------------------ specs ---


def conv(cout, k, stride, pad, relu):
    return {"kind": "conv", "cout": cout, "k": k, "stride": stride, "pad": pad, "relu": relu}


def dw(k, stride, pad, relu):
    return {"kind": "dw", "k": k, "stride": stride, "pad": pad, "relu": relu}


def dense(out, relu):
    return {"kind": "dense", "out": out, "relu": relu}


MAXPOOL = {"kind": "maxpool2"}
AVGPOOL = {"kind": "avgpool"}


def layer_node(spec):
    return ("layer", spec)


def residual(*specs):
    return ("residual", list(specs))


def _inverted_residual(nodes, cin, cout, t, s):
    seq = [conv(cin * t, 1, 1, 0, True), dw(3, s, 1, True), conv(cout, 1, 1, 0, False)]
    if s == 1 and cin == cout:
        nodes.append(residual(*seq))
    else:
        nodes.extend(layer_node(l) for l in seq)


def lenet5():
    return {
        "name": "lenet5",
        "input": (28, 28, 1),
        "classes": 10,
        "nodes": [
            layer_node(conv(6, 5, 1, 0, True)),
            layer_node(MAXPOOL),
            layer_node(conv(16, 5, 1, 0, True)),
            layer_node(MAXPOOL),
            layer_node(dense(120, True)),
            layer_node(dense(84, True)),
            layer_node(dense(10, False)),
        ],
    }


def cifar_cnn():
    return {
        "name": "cifar_cnn",
        "input": (32, 32, 3),
        "classes": 10,
        "nodes": [
            layer_node(conv(16, 3, 1, 1, True)),
            layer_node(MAXPOOL),
            layer_node(conv(32, 3, 1, 1, True)),
            layer_node(MAXPOOL),
            layer_node(conv(64, 3, 1, 1, True)),
            layer_node(MAXPOOL),
            layer_node(dense(10, False)),
        ],
    }


def mcunet_vww():
    nodes = [layer_node(conv(8, 3, 2, 1, True))]
    blocks = [
        (8, 16, 2, 2), (16, 16, 2, 1), (16, 16, 2, 1),
        (16, 24, 2, 2), (24, 24, 2, 1), (24, 24, 2, 1),
        (24, 32, 2, 2), (32, 32, 2, 1), (32, 32, 2, 1), (32, 32, 2, 1),
        (32, 48, 2, 2), (48, 48, 2, 1), (48, 48, 2, 1),
        (48, 64, 2, 1), (64, 64, 2, 1),
    ]
    for cin, cout, t, s in blocks:
        _inverted_residual(nodes, cin, cout, t, s)
    nodes += [layer_node(AVGPOOL), layer_node(dense(2, False))]
    return {"name": "mcunet_vww", "input": (64, 64, 3), "classes": 2, "nodes": nodes}


def mobilenet_v1():
    nodes = [layer_node(conv(8, 3, 1, 1, True))]
    pairs = [(16, 1), (32, 2), (32, 1), (64, 2), (64, 1), (128, 2),
             (128, 1), (128, 1), (128, 1), (128, 1), (128, 1), (256, 2), (256, 1)]
    for cout, s in pairs:
        nodes.append(layer_node(dw(3, s, 1, True)))
        nodes.append(layer_node(conv(cout, 1, 1, 0, True)))
    nodes += [layer_node(AVGPOOL), layer_node(dense(100, False))]
    return {"name": "mobilenet_v1", "input": (32, 32, 3), "classes": 100, "nodes": nodes}


MODELS = {m["name"]: m for m in (lenet5(), cifar_cnn(), mcunet_vww(), mobilenet_v1())}

# --------------------------------------------------------------- analysis ---


@dataclasses.dataclass
class QInfo:
    """Static info for one quantizable layer (Rust ``QLayerInfo`` twin)."""

    kind: str
    in_shape: tuple
    out_shape: tuple
    k: int
    stride: int
    pad: int
    relu: bool
    w_shape: tuple  # canonical layout: conv [O,K,K,Ci], dw [C,K,K], dense [O,I]
    b_len: int
    site_in: int
    site_out: int
    is_last: bool
    macs: int


def _out_shape(l, s):
    if l["kind"] == "conv":
        ho = (s[0] + 2 * l["pad"] - l["k"]) // l["stride"] + 1
        wo = (s[1] + 2 * l["pad"] - l["k"]) // l["stride"] + 1
        return (ho, wo, l["cout"])
    if l["kind"] == "dw":
        ho = (s[0] + 2 * l["pad"] - l["k"]) // l["stride"] + 1
        wo = (s[1] + 2 * l["pad"] - l["k"]) // l["stride"] + 1
        return (ho, wo, s[2])
    if l["kind"] == "dense":
        return (1, 1, l["out"])
    if l["kind"] == "maxpool2":
        return (s[0] // 2, s[1] // 2, s[2])
    if l["kind"] == "avgpool":
        return (1, 1, s[2])
    raise ValueError(l)


def _qinfo(l, s, site_in, site_out):
    out = _out_shape(l, s)
    if l["kind"] == "conv":
        return QInfo("conv", s, out, l["k"], l["stride"], l["pad"], l["relu"],
                     (l["cout"], l["k"], l["k"], s[2]), l["cout"], site_in, site_out, False,
                     out[0] * out[1] * l["cout"] * l["k"] * l["k"] * s[2])
    if l["kind"] == "dw":
        return QInfo("dw", s, out, l["k"], l["stride"], l["pad"], l["relu"],
                     (s[2], l["k"], l["k"]), s[2], site_in, site_out, False,
                     out[0] * out[1] * s[2] * l["k"] * l["k"])
    if l["kind"] == "dense":
        i = s[0] * s[1] * s[2]
        return QInfo("dense", (1, 1, i), out, 1, 1, 0, l["relu"],
                     (l["out"], i), l["out"], site_in, site_out, False, i * l["out"])
    return None


def analyze(spec):
    """Canonical site/layer walk — must agree with Rust ``models::analyze``."""
    layers, residuals = [], []
    shape = spec["input"]
    site, n_sites = 0, 1
    for node_kind, payload in spec["nodes"]:
        if node_kind == "layer":
            info = _qinfo(payload, shape, site, n_sites)
            if info is not None:
                site = n_sites
                n_sites += 1
                shape = info.out_shape
                layers.append(info)
            else:
                shape = _out_shape(payload, shape)
        else:  # residual
            skip_site, in_shape = site, shape
            bshape, bsite = shape, site
            for l in payload:
                info = _qinfo(l, bshape, bsite, n_sites)
                assert info is not None
                bsite = n_sites
                n_sites += 1
                bshape = info.out_shape
                layers.append(info)
            assert bshape == in_shape, "residual branch must preserve shape"
            residuals.append((skip_site, bsite, n_sites))
            site = n_sites
            n_sites += 1
    if layers:
        layers[-1].is_last = True
    return layers, n_sites, residuals

# ------------------------------------------------------------ float model ---


def init_params(spec, rng: np.random.Generator):
    """He-init float parameters in the canonical layout."""
    layers, _, _ = analyze(spec)
    params = []
    for info in layers:
        fan_in = {"conv": info.k * info.k * info.in_shape[2],
                  "dw": info.k * info.k,
                  "dense": info.in_shape[2]}[info.kind]
        std = np.sqrt(2.0 / fan_in)
        params.append({
            "w": jnp.asarray(rng.normal(0, std, info.w_shape).astype(np.float32)),
            "b": jnp.asarray((rng.normal(0, 0.01, info.b_len)).astype(np.float32)),
        })
    return params


def _float_layer(l, p, x):
    if l["kind"] == "conv":
        w = jnp.transpose(p["w"], (1, 2, 3, 0))  # [O,K,K,Ci] -> HWIO
        y = jax.lax.conv_general_dilated(
            x, w, (l["stride"], l["stride"]),
            [(l["pad"], l["pad"])] * 2,
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        y = y + p["b"][None, None, None, :]
        return jax.nn.relu(y) if l["relu"] else y
    if l["kind"] == "dw":
        c = x.shape[-1]
        w = jnp.transpose(p["w"], (1, 2, 0))[:, :, None, :]  # [C,K,K] -> [K,K,1,C]
        y = jax.lax.conv_general_dilated(
            x, w, (l["stride"], l["stride"]),
            [(l["pad"], l["pad"])] * 2,
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
            feature_group_count=c)
        y = y + p["b"][None, None, None, :]
        return jax.nn.relu(y) if l["relu"] else y
    if l["kind"] == "dense":
        y = x.reshape(x.shape[0], -1) @ p["w"].T + p["b"][None, :]
        return jax.nn.relu(y) if l["relu"] else y
    if l["kind"] == "maxpool2":
        b, h, w_, c = x.shape
        return x.reshape(b, h // 2, 2, w_ // 2, 2, c).max(axis=(2, 4))
    if l["kind"] == "avgpool":
        return x.mean(axis=(1, 2), keepdims=True)
    raise ValueError(l)


def float_forward(spec, params, x, record=None):
    """Differentiable float forward. With ``record`` (a list), appends the
    per-site abs-max — the calibration hook (site order == Rust walk)."""
    def rec(t):
        if record is not None:
            record.append(float(jnp.abs(t).max()))
    rec(x)
    li = 0
    for node_kind, payload in spec["nodes"]:
        if node_kind == "layer":
            is_q = payload["kind"] in ("conv", "dw", "dense")
            if is_q:
                x = _float_layer(payload, params[li], x)
                li += 1
                rec(x)
            else:
                x = _float_layer(payload, None, x)
        else:
            skip = x
            b = x
            for l in payload:
                b = _float_layer(l, params[li], b)
                li += 1
                rec(b)
            x = skip + b
            rec(x)
    return x.reshape(x.shape[0], -1)


def float_forward_traced(spec, params, x):
    """Record-free forward for jit/grad."""
    return float_forward(spec, params, x, record=None)

# ---------------------------------------------------------- integer model ---


def _im2col(x, k, stride, pad):
    """[B,H,W,C] int8 → patches [B, Ho·Wo, K·K·C] with (ky,kx,c) feature
    order — identical to the Rust conv weight layout [oc][ky][kx][ic]."""
    if pad:
        x = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    b, h, w, c = x.shape
    ho = (h - k) // stride + 1
    wo = (w - k) // stride + 1
    iy = (jnp.arange(ho) * stride)[:, None] + jnp.arange(k)[None, :]  # [ho,k]
    ix = (jnp.arange(wo) * stride)[:, None] + jnp.arange(k)[None, :]  # [wo,k]
    # [B, ho, k, w, C] -> [B, ho, k, wo, k, C]
    p = x[:, iy.reshape(-1), :, :].reshape(b, ho, k, w, c)
    p = p[:, :, :, ix.reshape(-1), :].reshape(b, ho, k, wo, k, c)
    p = jnp.transpose(p, (0, 1, 3, 2, 4, 5))  # [B, ho, wo, ky, kx, C]
    return p.reshape(b, ho * wo, k * k * c), ho, wo


def _pad_lanes(a, axis, mult):
    padw = (-a.shape[axis]) % mult
    if padw == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, padw)
    return jnp.pad(a, widths)


def _q_gemm(acts_i8, w_i8, bias, m, shift, relu, out_i32=False):
    """All conv/dense MACs route here: in-graph packing (8-bit lanes,
    values may sit on coarser grids) + the Pallas packed GEMM."""
    acts_p = _pad_lanes(acts_i8, 1, 4)
    w_p = _pad_lanes(w_i8, 1, 4)
    w_packed = pack_weights_jnp(w_p, 8)
    return packed_gemm(acts_p, w_packed, bias, m.astype(jnp.int32),
                       shift.astype(jnp.int32), bits=8, relu=relu, out_i32=out_i32)


def _q_layer(l, info, x, w, bias, m, shift):
    if l["kind"] == "conv":
        b = x.shape[0]
        patches, ho, wo = _im2col(x, l["k"], l["stride"], l["pad"])
        acts = patches.reshape(-1, patches.shape[-1])  # [B·P, KKC]
        wmat = w.reshape(w.shape[0], -1)  # [O, KKC]
        y = _q_gemm(acts, wmat, bias, m, shift, l["relu"])
        return y.reshape(b, ho, wo, w.shape[0])
    if l["kind"] == "dw":
        b = x.shape[0]
        k = l["k"]
        patches, ho, wo = _im2col(x, k, l["stride"], l["pad"])  # [B,P,KK·C]
        c = x.shape[-1]
        p4 = patches.reshape(b, ho * wo, k * k, c).astype(jnp.int32)
        acc = jnp.einsum("bptc,ct->bpc", p4, w.reshape(c, k * k).astype(jnp.int32))
        acc = acc + bias[None, None, :].astype(jnp.int32)
        y = requantize_jnp(acc, m, shift, l["relu"])
        return y.reshape(b, ho, wo, c)
    if l["kind"] == "dense":
        flat = x.reshape(x.shape[0], -1)
        if info.is_last:
            return _q_gemm(flat, w, bias, m, shift, False, out_i32=True)
        return _q_gemm(flat, w, bias, m, shift, l["relu"])
    if l["kind"] == "maxpool2":
        b, h, w_, c = x.shape
        return x.reshape(b, h // 2, 2, w_ // 2, 2, c).max(axis=(2, 4))
    if l["kind"] == "avgpool":
        s = x.astype(jnp.int32).sum(axis=(1, 2), keepdims=True)
        n = x.shape[1] * x.shape[2]
        return jnp.clip(jnp.floor_divide(s + n // 2, n), -128, 127).astype(jnp.int8)
    raise ValueError(l)


def _qadd(a, rq_a_m, rq_a_s, b, rq_b_m, rq_b_s):
    """Residual add with per-input rescale (<<8 pre-shift) — bit-exact
    twin of Rust ``nn::layers::qadd``."""
    ra = rounding_rshift_jnp(srdhm_jnp(a.astype(jnp.int32) << 8, rq_a_m), rq_a_s)
    rb = rounding_rshift_jnp(srdhm_jnp(b.astype(jnp.int32) << 8, rq_b_m), rq_b_s)
    return jnp.clip(ra + rb, -128, 127).astype(jnp.int8)


def build_qforward(spec) -> Callable:
    """Build the integer inference function to be AOT-lowered.

    Signature: ``f(images_i8, *w_and_b, m_vec, shift_vec[, res_m, res_shift])
    → (logits_i32, preds_i32)`` where ``w_and_b`` interleaves each
    quantizable layer's int8 weights and int32 bias in canonical order.
    """
    layers, _, residuals = analyze(spec)
    n_res = len(residuals)

    def qforward(images, *rest):
        nl = len(layers)
        ws = rest[0:2 * nl:2]
        bs = rest[1:2 * nl:2]
        m_vec, shift_vec = rest[2 * nl], rest[2 * nl + 1]
        if n_res:
            res_m, res_shift = rest[2 * nl + 2], rest[2 * nl + 3]
        li = 0
        res_i = 0
        x = images
        logits = None
        for node_kind, payload in spec["nodes"]:
            if node_kind == "layer":
                if payload["kind"] in ("conv", "dw", "dense"):
                    info = layers[li]
                    y = _q_layer(payload, info, x, ws[li], bs[li], m_vec[li], shift_vec[li])
                    li += 1
                    if info.is_last:
                        logits = y
                        break
                    x = y
                else:
                    x = _q_layer(payload, None, x, None, None, None, None)
            else:
                skip = x
                b = x
                for l in payload:
                    info = layers[li]
                    b = _q_layer(l, info, b, ws[li], bs[li], m_vec[li], shift_vec[li])
                    li += 1
                x = _qadd(skip, res_m[res_i, 0], res_shift[res_i, 0],
                          b, res_m[res_i, 1], res_shift[res_i, 1])
                res_i += 1
        assert logits is not None, "model must end in a dense logits layer"
        preds = jnp.argmax(logits, axis=1).astype(jnp.int32)
        return logits, preds

    return qforward


def qforward_arg_specs(spec, batch):
    """ShapeDtypeStructs for AOT lowering + the runtime manifest."""
    layers, _, residuals = analyze(spec)
    h, w, c = spec["input"]
    args = [jax.ShapeDtypeStruct((batch, h, w, c), jnp.int8)]
    for info in layers:
        args.append(jax.ShapeDtypeStruct(info.w_shape, jnp.int8))
        args.append(jax.ShapeDtypeStruct((info.b_len,), jnp.int32))
    nl = len(layers)
    args.append(jax.ShapeDtypeStruct((nl,), jnp.int32))  # m_vec
    args.append(jax.ShapeDtypeStruct((nl,), jnp.int32))  # shift_vec
    if residuals:
        r = len(residuals)
        args.append(jax.ShapeDtypeStruct((r, 2), jnp.int32))  # res_m
        args.append(jax.ShapeDtypeStruct((r, 2), jnp.int32))  # res_shift
    return args
