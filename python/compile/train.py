"""Build-time training on synthetic datasets + `.mpw` artifact export.

Substitution note (DESIGN.md §5): MNIST/CIFAR-10/VWW/ImageNet are not
available in this environment, so each Table-3 model is trained on a
synthetic prototype-classification dataset whose class margin is tuned
to give the graded bit-width sensitivity the paper's DSE relies on.
Quantization here is post-training (the paper's fine-tuning step is
per-DSE-config and is replaced by PTQ over calibrated scales).

The exported `.mpw` byte format is specified in
``rust/src/models/format.rs``; the Rust loader validates the embedded
spec against its own zoo, so structural drift fails loudly.

Python runs ONCE (``make artifacts``); nothing here is on the request
path.
"""

from __future__ import annotations

import struct
import sys
import time
from pathlib import Path

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from . import model as M

# ------------------------------------------------------------- synthetic ---


def smooth(img: np.ndarray, passes: int = 2) -> np.ndarray:
    """3×3 box blur, edge-clipped — same construction as the Rust twin."""
    out = img.copy()
    h, w, _ = img.shape
    for _ in range(passes):
        src = out.copy()
        acc = np.zeros_like(src)
        cnt = np.zeros(src.shape[:2] + (1,), dtype=np.float32)
        for dy in (-1, 0, 1):
            for dx in (-1, 0, 1):
                ys = slice(max(dy, 0), h + min(dy, 0))
                yd = slice(max(-dy, 0), h + min(-dy, 0))
                xs = slice(max(dx, 0), w + min(dx, 0))
                xd = slice(max(-dx, 0), w + min(-dx, 0))
                acc[yd, xd] += src[ys, xs]
                cnt[yd, xd] += 1
        out = acc / cnt
    return out


def synth_dataset(proto_seed: int, sample_seed: int, n: int, shape, classes: int,
                  noise: float):
    """Prototype + noise classification set; images in [-1, 1].

    Prototypes (the *task*) come from ``proto_seed``; sample noise from
    ``sample_seed`` — train/test splits share prototypes and differ only
    in samples.
    """
    prng = np.random.default_rng(proto_seed)
    protos = []
    for _ in range(classes):
        p = smooth(prng.normal(0, 1, shape).astype(np.float32))
        p = np.clip(p / max(np.abs(p).max(), 1e-6), -1, 1)
        protos.append(p)
    rng = np.random.default_rng(sample_seed)
    images = np.zeros((n, *shape), dtype=np.float32)
    labels = np.zeros(n, dtype=np.int64)
    for i in range(n):
        c = i % classes
        gain = 0.8 + 0.4 * rng.random()
        images[i] = np.clip(protos[c] * gain + rng.normal(0, noise, shape), -1, 1)
        labels[i] = c
    return images, labels

# ---------------------------------------------------------------- training ---


def cross_entropy(logits, labels):
    logp = jax.nn.log_softmax(logits)
    return -jnp.take_along_axis(logp, labels[:, None], axis=1).mean()


def adam_init(params):
    z = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": z, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": 0}


def adam_step(params, grads, state, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mh = jax.tree_util.tree_map(lambda m: m / (1 - b1**t), m)
    vh = jax.tree_util.tree_map(lambda v: v / (1 - b2**t), v)
    params = jax.tree_util.tree_map(
        lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + eps), params, mh, vh
    )
    return params, {"m": m, "v": v, "t": t}


def train_model(spec, seed=0, n_train=2048, n_test=512, epochs=6, batch=64, noise=0.35,
                lr=2e-3, log=print):
    """Train the float model; returns (params, test set, float accuracy)."""
    shape = spec["input"]
    classes = spec["classes"]
    xs, ys = synth_dataset(seed, seed + 1, n_train, shape, classes, noise)
    xt, yt = synth_dataset(seed, seed + 2, n_test, shape, classes, noise)
    rng = np.random.default_rng(seed + 2)
    params = M.init_params(spec, rng)

    @jax.jit
    def loss_fn(params, x, y):
        logits = M.float_forward_traced(spec, params, x)
        return cross_entropy(logits, y)

    grad_fn = jax.jit(jax.value_and_grad(loss_fn))

    @jax.jit
    def acc_fn(params, x, y):
        logits = M.float_forward_traced(spec, params, x)
        return (jnp.argmax(logits, axis=1) == y).mean()

    state = adam_init(params)
    steps = n_train // batch
    order = np.arange(n_train)
    for ep in range(epochs):
        rng.shuffle(order)
        tot = 0.0
        for s in range(steps):
            idx = order[s * batch:(s + 1) * batch]
            loss, grads = grad_fn(params, jnp.asarray(xs[idx]), jnp.asarray(ys[idx]))
            params, state = adam_step(params, grads, state, lr=lr)
            tot += float(loss)
        acc = float(acc_fn(params, jnp.asarray(xt), jnp.asarray(yt)))
        log(f"  epoch {ep + 1}/{epochs}: loss {tot / steps:.4f} test-acc {acc:.3f}")
    float_acc = float(acc_fn(params, jnp.asarray(xt), jnp.asarray(yt)))
    return params, (xt, yt), float_acc


def calibrate(spec, params, images: np.ndarray) -> np.ndarray:
    """Per-site int8 scales from abs-max over the calibration batch
    (site walk identical to Rust ``models::infer::calibrate``)."""
    layers, n_sites, _ = M.analyze(spec)
    maxes = np.zeros(n_sites, dtype=np.float64)
    for i in range(len(images)):
        rec = []
        M.float_forward(spec, params, jnp.asarray(images[i:i + 1]), record=rec)
        assert len(rec) == n_sites, (len(rec), n_sites)
        maxes = np.maximum(maxes, rec)
    return (np.maximum(maxes, 1e-6) / 128.0).astype(np.float32)

# ------------------------------------------------------------------ export ---

_LKIND = {"conv": 0, "dw": 1, "dense": 2, "maxpool2": 3, "avgpool": 4}


def _pack_layer(l) -> bytes:
    out = struct.pack("<B", _LKIND[l["kind"]])
    if l["kind"] == "conv":
        out += struct.pack("<IIIIB", l["cout"], l["k"], l["stride"], l["pad"], int(l["relu"]))
    elif l["kind"] == "dw":
        out += struct.pack("<IIIB", l["k"], l["stride"], l["pad"], int(l["relu"]))
    elif l["kind"] == "dense":
        out += struct.pack("<IB", l["out"], int(l["relu"]))
    return out


def export_mpw(path: Path, spec, params, sites, float_acc, test_images, test_labels):
    """Serialize the `.mpw` artifact (see rust/src/models/format.rs)."""
    name = spec["name"].encode()
    h, w, c = spec["input"]
    out = bytearray()
    out += b"MPW1"
    out += struct.pack("<I", len(name)) + name
    out += struct.pack("<IIII", h, w, c, spec["classes"])
    out += struct.pack("<I", len(spec["nodes"]))
    for kind, payload in spec["nodes"]:
        if kind == "layer":
            out += b"\x00" + _pack_layer(payload)
        else:
            out += b"\x01" + struct.pack("<I", len(payload))
            for l in payload:
                out += _pack_layer(l)
    out += struct.pack("<I", len(params))
    for p in params:
        wf = np.asarray(p["w"], dtype=np.float32).reshape(-1)
        bf = np.asarray(p["b"], dtype=np.float32).reshape(-1)
        out += struct.pack("<II", wf.size, bf.size)
        out += wf.tobytes() + bf.tobytes()
    sites = np.asarray(sites, dtype=np.float32)
    out += struct.pack("<I", sites.size) + sites.tobytes()
    out += struct.pack("<f", float_acc)
    imgs = np.asarray(test_images, dtype=np.float32)
    out += struct.pack("<I", imgs.shape[0]) + imgs.tobytes()
    out += np.asarray(test_labels, dtype=np.uint8).tobytes()
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_bytes(bytes(out))


# Per-model training budgets (tuned for single-core CPU build time).
TRAIN_CFG = {
    "lenet5": dict(epochs=6, n_train=2048, noise=0.45, lr=2e-3),
    "cifar_cnn": dict(epochs=6, n_train=2048, noise=0.40, lr=2e-3),
    "mcunet_vww": dict(epochs=5, n_train=1536, noise=0.40, lr=2e-3),
    "mobilenet_v1": dict(epochs=8, n_train=3000, noise=0.30, lr=2e-3),
}


def main(out_dir: Path, only=None):
    for name, spec in M.MODELS.items():
        if only and name not in only:
            continue
        path = out_dir / "weights" / f"{name}.mpw"
        if path.exists():
            print(f"[train] {name}: artifact exists, skipping")
            continue
        cfg = TRAIN_CFG[name]
        print(f"[train] {name} {spec['input']} classes={spec['classes']} {cfg}")
        t0 = time.time()
        params, (xt, yt), facc = train_model(spec, seed=sum(name.encode()) * 7919, **cfg)
        sites = calibrate(spec, params, xt[:32])
        export_mpw(path, spec, params, sites, facc, xt, yt)
        print(f"[train] {name}: float acc {facc:.3f}, {time.time() - t0:.0f}s -> {path}")


if __name__ == "__main__":
    out = Path(sys.argv[1]) if len(sys.argv) > 1 else Path("../artifacts")
    main(out, only=sys.argv[2:] or None)
