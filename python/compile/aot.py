"""AOT lowering: JAX/Pallas → HLO **text** artifacts for the Rust runtime.

HLO text (not ``.serialize()``) is the interchange format: jax ≥ 0.5
emits HloModuleProtos with 64-bit instruction ids that xla_extension
0.5.1 rejects; the text parser reassigns ids and round-trips cleanly
(see /opt/xla-example/README.md).

Artifacts:

* ``<model>_qfwd_b<B>.hlo.txt`` — each Table-3 model's integer forward
  (weights/bias/requant params as runtime arguments: one HLO per model
  serves every DSE configuration),
* ``kernel_packed_gemm_{8,4,2}b.hlo.txt`` — the standalone L1 packed-MAC
  kernels at a reference shape,
* ``kernel_soft_simd_2b.hlo.txt`` — the Eq.(2) Mode-3 kernel,
* ``manifest.json`` — arg shapes/dtypes for the Rust runtime,
* ``xcheck.json`` — cross-language bit-exactness vectors (requantize,
  packing) consumed by the Rust integration tests.

Python runs ONCE at ``make artifacts``; never on the request path.
"""

from __future__ import annotations

import json
import sys
from pathlib import Path

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from . import quantize as Q
from .kernels.packed_mac import packed_gemm, soft_simd_gemm_2b, vmem_bytes_estimate

BATCH = 64

# Reference shapes for the standalone kernel artifacts.
KERNEL_M, KERNEL_I, KERNEL_O = 64, 256, 32


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec_json(s: jax.ShapeDtypeStruct):
    return {"shape": list(s.shape), "dtype": str(np.dtype(s.dtype))}


def lower_model(spec, batch: int):
    qf = M.build_qforward(spec)
    args = M.qforward_arg_specs(spec, batch)
    lowered = jax.jit(qf).lower(*args)
    return to_hlo_text(lowered), [_spec_json(a) for a in args]


def lower_kernels():
    """Standalone packed-GEMM kernels (one per mode) + the soft-SIMD one."""
    out = {}
    for bits in (8, 4, 2):
        lanes = 32 // bits
        args = [
            jax.ShapeDtypeStruct((KERNEL_M, KERNEL_I), jnp.int8),
            jax.ShapeDtypeStruct((KERNEL_O, KERNEL_I // lanes), jnp.uint32),
            jax.ShapeDtypeStruct((KERNEL_O,), jnp.int32),
            jax.ShapeDtypeStruct((), jnp.int32),
            jax.ShapeDtypeStruct((), jnp.int32),
        ]
        fn = lambda a, w, b, m, s, bits=bits: (
            packed_gemm(a, w, b, m, s, bits=bits, relu=True),
        )
        lowered = jax.jit(fn).lower(*args)
        out[f"kernel_packed_gemm_{bits}b"] = (to_hlo_text(lowered), [_spec_json(a) for a in args])
    args = [
        jax.ShapeDtypeStruct((KERNEL_M, KERNEL_I), jnp.int8),
        jax.ShapeDtypeStruct((KERNEL_O, KERNEL_I), jnp.int8),
        jax.ShapeDtypeStruct((KERNEL_O,), jnp.int32),
        jax.ShapeDtypeStruct((), jnp.int32),
        jax.ShapeDtypeStruct((), jnp.int32),
    ]
    fn = lambda a, w, b, m, s: (soft_simd_gemm_2b(a, w, b, m, s, relu=True),)
    lowered = jax.jit(fn).lower(*args)
    out["kernel_soft_simd_2b"] = (to_hlo_text(lowered), [_spec_json(a) for a in args])
    return out


def xcheck_vectors(seed=0xC0FFEE) -> dict:
    """Bit-exactness vectors the Rust tests replay against nn::quant."""
    rng = np.random.default_rng(seed)
    req = []
    for _ in range(64):
        scale = float(2.0 ** -(rng.random() * 14 + 0.01))
        rq = Q.Requant.from_real_scale(scale)
        acc = int(rng.integers(-(1 << 28), 1 << 28))
        relu = bool(rng.integers(0, 2))
        out = int(Q.requantize(np.array([acc]), rq, relu)[0])
        req.append({"acc": acc, "m": rq.m, "shift": rq.shift, "relu": relu, "out": out})
    packs = []
    for bits in (8, 4, 2):
        lanes = 32 // bits
        lo, hi = Q.qrange(bits)
        w = rng.integers(lo, hi + 1, lanes * 3).astype(np.int8)
        words = Q.pack_weight_stream(w, bits)
        packs.append({"bits": bits, "weights": w.tolist(), "words": [int(x) for x in words]})
    quant = []
    for bits in (8, 4, 2):
        vals = (rng.random(32).astype(np.float32) * 2 - 1) * 0.7
        q, s = Q.quantize_tensor(vals, bits)
        quant.append({
            "bits": bits,
            "values": [float(v) for v in vals],
            "q": q.tolist(),
            "scale": float(s),
        })
    return {"requantize": req, "pack": packs, "quantize": quant}


def main(out_dir: Path, only=None):
    out_dir.mkdir(parents=True, exist_ok=True)
    manifest = {"batch": BATCH, "models": {}, "kernels": {}, "vmem": {}}
    for name, spec in M.MODELS.items():
        if only and name not in only:
            continue
        path = out_dir / f"{name}_qfwd_b{BATCH}.hlo.txt"
        print(f"[aot] lowering {name} (batch {BATCH}) ...")
        hlo, args = lower_model(spec, BATCH)
        path.write_text(hlo)
        layers, n_sites, residuals = M.analyze(spec)
        manifest["models"][name] = {
            "file": path.name,
            "args": args,
            "n_layers": len(layers),
            "n_sites": n_sites,
            "n_residuals": len(residuals),
            "outputs": ["logits_i32", "preds_i32"],
        }
        print(f"[aot]   {path.name}: {len(hlo) / 1e6:.1f} MB, {len(args)} args")
    if not only:
        for kname, (hlo, args) in lower_kernels().items():
            path = out_dir / f"{kname}.hlo.txt"
            path.write_text(hlo)
            manifest["kernels"][kname] = {"file": path.name, "args": args}
            print(f"[aot]   {path.name}: {len(hlo) / 1e3:.0f} KB")
        for bits in (8, 4, 2):
            manifest["vmem"][f"{bits}b"] = vmem_bytes_estimate(bits, KERNEL_I)
    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=1))
    (out_dir / "xcheck.json").write_text(json.dumps(xcheck_vectors(), indent=1))
    print(f"[aot] wrote manifest + xcheck to {out_dir}")


if __name__ == "__main__":
    args = [a for a in sys.argv[1:] if not a.startswith("--")]
    out = Path(args[0]) if args else Path("../artifacts")
    main(out, only=args[1:] or None)
