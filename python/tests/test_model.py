"""L2 model tests: spec walk parity, shapes, float/int forward sanity."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile import quantize as Q


@pytest.mark.parametrize("name", list(M.MODELS))
def test_analyze_shapes(name):
    spec = M.MODELS[name]
    layers, n_sites, residuals = M.analyze(spec)
    assert layers[-1].is_last
    assert layers[-1].out_shape[2] == spec["classes"]
    # Site counts: input + one per quantizable layer + one per residual.
    assert n_sites == 1 + len(layers) + len(residuals)


def test_zoo_matches_rust_counts():
    # Mirrors the Rust zoo tests: layer counts per model.
    assert len(M.analyze(M.lenet5())[0]) == 5
    assert len(M.analyze(M.cifar_cnn())[0]) == 4
    assert len(M.analyze(M.mcunet_vww())[0]) == 47
    assert len(M.analyze(M.mobilenet_v1())[0]) == 28
    assert len(M.analyze(M.mcunet_vww())[2]) == 10


@pytest.mark.parametrize("name", ["lenet5", "cifar_cnn"])
def test_float_forward_shapes_and_record(name):
    spec = M.MODELS[name]
    rng = np.random.default_rng(0)
    params = M.init_params(spec, rng)
    x = jnp.asarray(rng.normal(0, 0.5, (2, *spec["input"])).astype(np.float32))
    rec = []
    out = M.float_forward(spec, params, x, record=rec)
    assert out.shape == (2, spec["classes"])
    assert len(rec) == M.analyze(spec)[1]


def _quantize_all(spec, params, sites, bits):
    layers, _, _ = M.analyze(spec)
    args = []
    ms, ss = [], []
    for info, p, b in zip(layers, params, [bits] * len(layers)):
        qw, bias, rq, _ = Q.quantize_layer(
            np.asarray(p["w"]), np.asarray(p["b"]),
            sites[info.site_in], sites[info.site_out], b)
        args += [jnp.asarray(qw.reshape(info.w_shape)), jnp.asarray(bias)]
        ms.append(rq.m)
        ss.append(rq.shift)
    args.append(jnp.asarray(np.array(ms, np.int32)))
    args.append(jnp.asarray(np.array(ss, np.int32)))
    return args


def test_qforward_tracks_float_lenet():
    """Int8 inference must agree with float inference on most samples."""
    spec = M.lenet5()
    rng = np.random.default_rng(1)
    params = M.init_params(spec, rng)
    x = rng.normal(0, 0.4, (16, *spec["input"])).astype(np.float32)
    # Calibrate sites.
    layers, n_sites, _ = M.analyze(spec)
    maxes = np.zeros(n_sites)
    for i in range(4):
        rec = []
        M.float_forward(spec, params, jnp.asarray(x[i:i+1]), record=rec)
        maxes = np.maximum(maxes, rec)
    sites = np.maximum(maxes, 1e-6) / 128.0
    fl = np.asarray(M.float_forward(spec, params, jnp.asarray(x)))
    qf = M.build_qforward(spec)
    imgs = np.clip(Q.round_half_away(x / sites[0]), -128, 127).astype(np.int8)
    args = _quantize_all(spec, params, sites, 8)
    logits, preds = qf(jnp.asarray(imgs), *args)
    agree = (np.asarray(preds) == fl.argmax(1)).mean()
    assert agree >= 0.8, f"int8 vs float prediction agreement {agree}"


def test_qforward_residual_model_runs():
    spec = M.mcunet_vww()
    rng = np.random.default_rng(2)
    params = M.init_params(spec, rng)
    layers, n_sites, residuals = M.analyze(spec)
    sites = np.full(n_sites, 0.02, np.float32)
    args = _quantize_all(spec, params, sites, 4)
    r = len(residuals)
    args.append(jnp.full((r, 2), 1 << 30, jnp.int32))
    args.append(jnp.full((r, 2), 8, jnp.int32))
    imgs = rng.integers(-128, 128, (2, *spec["input"])).astype(np.int8)
    qf = M.build_qforward(spec)
    logits, preds = qf(jnp.asarray(imgs), *args)
    assert logits.shape == (2, 2)
    assert preds.shape == (2,)


def test_im2col_matches_conv():
    """Patch order must equal the Rust weight layout (ky, kx, ic)."""
    rng = np.random.default_rng(3)
    x = rng.integers(-128, 128, (1, 6, 6, 3)).astype(np.int8)
    w = rng.integers(-8, 8, (4, 3, 3, 3)).astype(np.int8)
    patches, ho, wo = M._im2col(jnp.asarray(x), 3, 1, 1)
    acc = np.asarray(patches).astype(np.int64) @ w.reshape(4, -1).T.astype(np.int64)
    # Reference: plain lax conv in float (values are small — exact).
    import jax.lax as lax
    ref = lax.conv_general_dilated(
        x.astype(np.float32), np.transpose(w, (1, 2, 3, 0)).astype(np.float32),
        (1, 1), [(1, 1), (1, 1)], dimension_numbers=("NHWC", "HWIO", "NHWC"))
    np.testing.assert_array_equal(
        acc.reshape(1, 6, 6, 4), np.asarray(ref).astype(np.int64))
