"""Quantization spec tests (the Python mirror of rust/src/nn/quant.rs)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import quantize as Q


def test_qranges():
    assert Q.qrange(8) == (-128, 127)
    assert Q.qrange(4) == (-8, 7)
    assert Q.qrange(2) == (-2, 1)


def test_round_half_away_matches_rust_round():
    x = np.array([0.5, 1.5, -0.5, -1.5, 2.49, -2.49])
    np.testing.assert_array_equal(Q.round_half_away(x), [1, 2, -1, -2, 2, -2])


@settings(max_examples=40, deadline=None)
@given(scale=st.floats(1e-6, 0.999))
def test_requant_decomposition(scale):
    rq = Q.Requant.from_real_scale(scale)
    assert (1 << 30) <= rq.m < (1 << 31)
    assert abs(rq.real_scale() - scale) / scale < 1e-8


def test_srdhm_known():
    assert Q.srdhm(np.array([10]), 1 << 30)[0] == 5
    assert Q.srdhm(np.array([-10]), 1 << 30)[0] == -5
    assert Q.srdhm(np.array([3]), 1 << 30)[0] == 2  # 1.5 rounds up


def test_requantize_clamps_and_relu():
    rq = Q.Requant.from_real_scale(0.5)
    acc = np.array([10, -10, 1000, -1000])
    np.testing.assert_array_equal(Q.requantize(acc, rq, False), [5, -5, 127, -128])
    np.testing.assert_array_equal(Q.requantize(acc, rq, True), [5, 0, 127, 0])


@settings(max_examples=30, deadline=None)
@given(bits=st.sampled_from([8, 4, 2]), seed=st.integers(0, 2**31))
def test_quantize_tensor_on_grid(bits, seed):
    rng = np.random.default_rng(seed)
    w = rng.normal(0, 0.3, 50).astype(np.float32)
    q, s = Q.quantize_tensor(w, bits)
    lo, hi = Q.qrange(bits)
    assert q.min() >= lo and q.max() <= hi
    if np.abs(w).max() > 0:
        err = np.abs(q.astype(np.float32) * s - w)
        inside = np.abs(w / s) < hi
        assert (err[inside] <= s / 2 + 1e-5).all()


def test_quantize_layer_bias_scale():
    qw, bias, rq, s_w = Q.quantize_layer(
        np.array([1.0]), np.array([0.7]), 0.1, 1.0, 8)
    # bias_q = b / (s_in · s_w) with the MSE-searched scale.
    want = round(0.7 / (0.1 * s_w))
    assert abs(int(bias[0]) - want) <= 1
    assert abs(qw[0] * s_w - 1.0) < 0.05  # weight dequantizes near 1.0


def test_mse_scale_search_improves_int2():
    # The candidate search must beat (or match) plain abs-max scaling on
    # a heavy-tailed weight distribution at 2-bit.
    rng = np.random.default_rng(0)
    w = rng.normal(0, 0.2, 256).astype(np.float32)
    w[0] = 1.0  # outlier drives abs-max scaling off
    q, s = Q.quantize_tensor(w, 2)
    base = Q.symmetric_scale(float(np.abs(w).max()), 2)
    q0 = Q._quantize_at(w, np.float32(base), 2)
    mse_search = float(((w - q.astype(np.float32) * s) ** 2).sum())
    mse_naive = float(((w - q0.astype(np.float32) * base) ** 2).sum())
    assert mse_search <= mse_naive + 1e-6
