import sys
from pathlib import Path

import jax

# The quantization spec is 64-bit-exact; enable x64 before any tracing.
jax.config.update("jax_enable_x64", True)

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
