"""L1 correctness: the Pallas packed-MAC kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes, widths and requant parameters; every
comparison is exact (integer kernels admit no tolerance).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.packed_mac import (
    packed_gemm,
    soft_simd_gemm_2b,
    vmem_bytes_estimate,
)
from compile import quantize as Q


def _pad_lanes(a, lanes):
    pad = (-a.shape[1]) % lanes
    return np.pad(a, ((0, 0), (0, pad)))


def run_case(bits, m_dim, i_dim, o_dim, relu, out_i32, seed):
    rng = np.random.default_rng(seed)
    lanes = 32 // bits
    lo, hi = Q.qrange(bits)
    acts = rng.integers(-128, 128, (m_dim, i_dim)).astype(np.int8)
    w = rng.integers(lo, hi + 1, (o_dim, i_dim)).astype(np.int8)
    bias = rng.integers(-1000, 1000, o_dim).astype(np.int32)
    acts_p = _pad_lanes(acts, lanes)
    w_p = _pad_lanes(w, lanes)
    wp = ref.pack_weights_jnp(jnp.asarray(w_p), bits)
    m = jnp.int32(rng.integers(1 << 30, 1 << 31))
    shift = jnp.int32(rng.integers(0, 12))
    got = packed_gemm(jnp.asarray(acts_p), wp, jnp.asarray(bias), m, shift,
                      bits=bits, relu=relu, out_i32=out_i32)
    want = ref.packed_gemm_ref(jnp.asarray(acts_p), wp, jnp.asarray(bias),
                               bits, m, shift, relu, out_i32)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=25, deadline=None)
@given(
    bits=st.sampled_from([8, 4, 2]),
    m_dim=st.integers(1, 40),
    i_dim=st.integers(1, 96),
    o_dim=st.integers(1, 48),
    relu=st.booleans(),
    out_i32=st.booleans(),
    seed=st.integers(0, 2**31),
)
def test_packed_gemm_matches_ref(bits, m_dim, i_dim, o_dim, relu, out_i32, seed):
    run_case(bits, m_dim, i_dim, o_dim, relu, out_i32, seed)


@pytest.mark.parametrize("bits", [8, 4, 2])
def test_packed_gemm_tile_boundaries(bits):
    # Shapes exactly on / just over the Pallas tile sizes.
    from compile.kernels.packed_mac import TILE_M, TILE_O
    run_case(bits, TILE_M, 64, TILE_O, True, False, 1)
    run_case(bits, TILE_M + 1, 64, TILE_O + 1, False, False, 2)


@settings(max_examples=15, deadline=None)
@given(
    m_dim=st.integers(1, 24),
    i_dim=st.integers(1, 64),
    pairs=st.integers(1, 12),
    relu=st.booleans(),
    seed=st.integers(0, 2**31),
)
def test_soft_simd_gemm_matches_packed_ref(m_dim, i_dim, pairs, relu, seed):
    """Mode-3 factorised via Eq.(2) == the plain packed 2-bit GEMM."""
    rng = np.random.default_rng(seed)
    o_dim = pairs * 2
    acts = rng.integers(-128, 128, (m_dim, i_dim)).astype(np.int8)
    w2 = rng.integers(-2, 2, (o_dim, i_dim)).astype(np.int8)
    bias = rng.integers(-500, 500, o_dim).astype(np.int32)
    m = jnp.int32(rng.integers(1 << 30, 1 << 31))
    shift = jnp.int32(rng.integers(0, 10))
    got = soft_simd_gemm_2b(jnp.asarray(acts), jnp.asarray(w2), jnp.asarray(bias),
                            m, shift, relu=relu)
    acts_p = _pad_lanes(acts, 16)
    w_p = _pad_lanes(w2, 16)
    wp = ref.pack_weights_jnp(jnp.asarray(w_p), 2)
    want = ref.packed_gemm_ref(jnp.asarray(acts_p), wp, jnp.asarray(bias),
                               2, m, shift, relu, False)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=50, deadline=None)
@given(
    a=st.integers(-128, 127),
    we=st.integers(-2, 1),
    wo=st.integers(-2, 1),
)
def test_eq2_dual_product_exact(a, we, wo):
    """The guard-bit field extraction recovers both products exactly."""
    composed = ref.soft_simd_compose_ref(jnp.int8(we), jnp.int8(wo))
    lo, hi = ref.soft_simd_dual_ref(jnp.int8(a), composed)
    assert int(lo) == a * we
    assert int(hi) == a * wo


@settings(max_examples=30, deadline=None)
@given(bits=st.sampled_from([8, 4, 2]), n=st.integers(1, 64), seed=st.integers(0, 2**31))
def test_pack_unpack_round_trip(bits, n, seed):
    rng = np.random.default_rng(seed)
    lo, hi = Q.qrange(bits)
    lanes = 32 // bits
    n_pad = -(-n // lanes) * lanes
    w = np.zeros(n_pad, dtype=np.int8)
    w[:n] = rng.integers(lo, hi + 1, n)
    words = ref.pack_weights_jnp(jnp.asarray(w)[None, :], bits)
    back = ref.unpack_weights_jnp(words, bits)[0]
    np.testing.assert_array_equal(np.asarray(back), w.astype(np.int32))


def test_jnp_and_numpy_packers_agree():
    rng = np.random.default_rng(0)
    for bits in (8, 4, 2):
        lo, hi = Q.qrange(bits)
        lanes = 32 // bits
        w = rng.integers(lo, hi + 1, lanes * 5).astype(np.int8)
        a = np.asarray(ref.pack_weights_jnp(jnp.asarray(w), bits))
        b = Q.pack_weight_stream(w, bits)
        np.testing.assert_array_equal(a, b)


def test_vmem_estimate_compression_factors():
    for bits, vs_int8, vs_loads in ((8, 1.0, 4.0), (4, 2.0, 8.0), (2, 4.0, 16.0)):
        est = vmem_bytes_estimate(bits, 256)
        assert est["weight_compression_vs_int8"] == pytest.approx(vs_int8)
        assert est["weight_compression_vs_wordloads"] == pytest.approx(vs_loads)
        assert est["total_bytes"] < 16 << 20, "tile must fit VMEM"
