"""AOT path tests: HLO text emission + manifest/xcheck generation."""

import json

import jax
import numpy as np
import pytest

from compile import aot
from compile import model as M


def test_lower_lenet_hlo_text():
    hlo, args = aot.lower_model(M.lenet5(), 2)
    assert hlo.startswith("HloModule")
    assert "s8[2,28,28,1]" in hlo
    assert len(args) == 1 + 5 * 2 + 2


def test_lower_kernels_smoke():
    out = aot.lower_kernels()
    assert set(out) == {
        "kernel_packed_gemm_8b", "kernel_packed_gemm_4b",
        "kernel_packed_gemm_2b", "kernel_soft_simd_2b",
    }
    for name, (hlo, args) in out.items():
        assert hlo.startswith("HloModule"), name
        assert "u32" in hlo or "s8" in hlo


def test_xcheck_vectors_selfconsistent():
    from compile import quantize as Q
    v = aot.xcheck_vectors()
    assert len(v["requantize"]) == 64
    for case in v["requantize"][:8]:
        got = int(Q.requantize(np.array([case["acc"]]),
                               Q.Requant(case["m"], case["shift"]), case["relu"])[0])
        assert got == case["out"]
    for p in v["pack"]:
        words = Q.pack_weight_stream(np.array(p["weights"], np.int8), p["bits"])
        assert [int(x) for x in words] == p["words"]
