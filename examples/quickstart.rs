//! Quickstart: the three-layer stack in one file.
//!
//! 1. Load the AOT-compiled Pallas packed-MAC kernel (L1, lowered by
//!    `python/compile/aot.py`) and execute it via PJRT from Rust.
//! 2. Run the *same* computation as an `nn_mac` kernel program on the
//!    cycle-accurate RISC-V core (L3) and compare bit-for-bit.
//!
//! Run with: `cargo run --release --example quickstart`
//! (needs `make artifacts` for step 1; step 2 always works).

use mpnn::isa::MacMode;
use mpnn::kernels::dense::DenseSpec;
use mpnn::kernels::run::run_dense;
use mpnn::nn::quant::Requant;
use mpnn::rng::Rng;

fn main() -> mpnn::Result<()> {
    let mut rng = Rng::new(42);
    // A small quantized dense layer: 256 inputs, 32 outputs, 4-bit weights.
    let (i, o) = (256usize, 32usize);
    let mode = MacMode::W4;
    let acts: Vec<i8> = (0..i).map(|_| rng.i8()).collect();
    let w: Vec<i8> = (0..o * i).map(|_| rng.int_bits(4)).collect();
    let bias: Vec<i32> = (0..o).map(|_| rng.range_i32(-500, 500)).collect();
    let rq = Requant::from_real_scale(0.002);

    // --- L3: the RISC-V ISS running the nn_mac_4b kernel -----------------
    let spec = DenseSpec { in_dim: i, out_dim: o, rq, relu: true, out_i32: false };
    let (iss_out, _, perf) = run_dense(spec, Some(mode), &acts, &w, &bias)?;
    let (base_out, _, base_perf) = run_dense(spec, None, &acts, &w, &bias)?;
    assert_eq!(iss_out, base_out, "extended and baseline kernels agree");
    println!("ISS: {} MACs in {} cycles (baseline {} cycles → {:.1}x speedup)",
        perf.macs, perf.cycles, base_perf.cycles,
        base_perf.cycles as f64 / perf.cycles as f64);

    // --- L1/L2: the Pallas packed-MAC kernel via PJRT ---------------------
    let root = mpnn::runtime::default_artifacts_dir();
    if !root.join("kernel_packed_gemm_4b.hlo.txt").exists() {
        println!("(skipping PJRT half — run `make artifacts` first)");
        return Ok(());
    }
    let mut session = mpnn::runtime::Session::open(&root)?;
    // The kernel artifact is fixed at M=64×I=256×O=32; replicate the
    // activations across the batch and check row 0.
    let m = 64usize;
    let mut batch = Vec::with_capacity(m * i);
    for _ in 0..m {
        batch.extend_from_slice(&acts);
    }
    let mut packed = Vec::new();
    for row in w.chunks(i) {
        packed.extend(mpnn::isa::custom::pack_weight_stream(mode, row));
    }
    let exe = session.load("kernel_packed_gemm_4b")?;
    let outs = mpnn::runtime::execute(
        exe,
        &[
            mpnn::runtime::lit_i8(&[m, i], &batch)?,
            mpnn::runtime::lit_u32(&[o, packed.len() / o], &packed)?,
            mpnn::runtime::lit_i32(&[o], &bias)?,
            mpnn::runtime::lit_i32(&[], &[rq.m])?,
            mpnn::runtime::lit_i32(&[], &[rq.shift])?,
        ],
    )?;
    let pjrt_out = outs[0].to_vec::<i8>()?;
    assert_eq!(&pjrt_out[..o], &iss_out[..], "Pallas kernel == RISC-V kernel, bit-exact");
    println!("PJRT: Pallas packed-MAC kernel output matches the ISS bit-for-bit ({o} outputs)");
    println!("quickstart OK");
    Ok(())
}
