//! ISA playground: assemble a mixed-precision program with all three
//! `nn_mac` instructions, disassemble it, execute it cycle-accurately
//! and read the Ibex-style performance counters.
//!
//! Run with: `cargo run --release --example isa_playground`

use mpnn::asm::Asm;
use mpnn::isa::custom::{pack_acts, pack_weights};
use mpnn::isa::{csr, disasm::disasm, reg, MacMode};
use mpnn::sim::{Core, CoreConfig, ExitReason};

fn main() {
    let mut a = Asm::new();

    // Accumulate the same dot product three ways: as 4 MACs of 8-bit
    // weights, 8 MACs of 4-bit, 16 MACs of 2-bit.
    a.li(reg::A0, 0);
    // Activations 1..16 in four packed registers.
    for (j, r) in [reg::A2, reg::A3, reg::A4, reg::A5].iter().enumerate() {
        let base = 4 * j as i8;
        a.li(*r, pack_acts([base + 1, base + 2, base + 3, base + 4]) as i32);
    }
    // Mode-1: 4 weights of 8-bit.
    a.li(reg::T0, pack_weights(MacMode::W8, &[1, -1, 2, -2]) as i32);
    a.nn_mac(MacMode::W8, reg::A0, reg::A2, reg::T0);
    // Mode-2: 8 weights of 4-bit (register pair a2,a3).
    a.li(reg::T0, pack_weights(MacMode::W4, &[1, 1, 1, 1, -1, -1, -1, -1]) as i32);
    a.nn_mac(MacMode::W4, reg::A0, reg::A2, reg::T0);
    // Mode-3: 16 weights of 2-bit (register quad a2..a5).
    a.li(reg::T0, pack_weights(MacMode::W2, &[1; 16]) as i32);
    a.nn_mac(MacMode::W2, reg::A0, reg::A2, reg::T0);
    // Read the counters from CSRs like firmware would.
    a.csrr(reg::S0, csr::MCYCLE);
    a.csrr(reg::S1, csr::MINSTRET);
    a.csrr(reg::S2, csr::MHPM_MACS);
    a.halt();

    let prog = a.assemble();
    println!("--- disassembly ---");
    for (i, ins) in prog.iter().enumerate() {
        println!("{:4x}:  {}", 4 * i, disasm(*ins));
    }

    let mut core = Core::new(CoreConfig { mem_size: 4096, ..Default::default() }, prog, 0);
    assert_eq!(core.run(10_000), ExitReason::Ecall);
    println!("--- execution ---");
    println!("accumulator a0 = {}", core.regs[reg::A0 as usize] as i32);
    println!("mcycle   (s0) = {}", core.regs[reg::S0 as usize]);
    println!("minstret (s1) = {}", core.regs[reg::S1 as usize]);
    println!("MACs     (s2) = {}", core.regs[reg::S2 as usize]);
    println!(
        "28 MACs retired by 3 instructions — {:.1} MACs/cycle over the whole program",
        core.perf.macs_per_cycle()
    );
}
