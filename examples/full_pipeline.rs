//! End-to-end driver (the repository's E2E validation): loads a trained
//! model artifact, picks a mixed-precision configuration, then runs the
//! SAME quantized inference through all three execution paths —
//!
//! 1. the batched PJRT artifact (L2 JAX calling the L1 Pallas kernel),
//! 2. the Rust host reference,
//! 3. the cycle-accurate RISC-V core executing the `nn_mac` kernels —
//!
//! verifies they agree bit-for-bit, and reports accuracy, cycles,
//! speedup and the Table-4-style energy numbers for the workload.
//!
//! Run with: `cargo run --release --example full_pipeline [model]`

use mpnn::energy::{ASIC_BASELINE, ASIC_MODIFIED};
use mpnn::exp::ExpOpts;
use mpnn::models::infer::{qforward, quantize_input, quantize_model};
use mpnn::models::sim_exec::{baseline_modes, modes_for, run_model};
use mpnn::sim::MacUnitConfig;

fn main() -> mpnn::Result<()> {
    let name = std::env::args().nth(1).unwrap_or_else(|| "lenet5".to_string());
    let opts = ExpOpts::default();
    let model = opts.load_model(&name)?;
    let analysis = mpnn::models::analyze(&model.spec);
    let n = analysis.layers.len();
    println!(
        "model {name}: {} quantizable layers, {} MACs, float acc {:.1}%",
        n,
        analysis.total_macs,
        model.float_acc * 100.0
    );

    // A representative mixed-precision configuration: the sensitive early
    // quarter at 8-bit, the rest at 4-bit, and for the small conv-only
    // models the tail drops to 2-bit (the Fig.-8 selection structure).
    let mut bits = vec![4u32; n];
    for (i, b) in bits.iter_mut().enumerate() {
        if i == 0 || i < n / 4 {
            *b = 8;
        } else if n <= 8 && i >= 3 * n / 4 {
            *b = 2;
        }
    }
    println!("configuration: {bits:?}");
    let qm = quantize_model(&model.spec, &model.params, &model.sites, &bits);

    // --- path 1+2: PJRT batch vs host reference -------------------------
    let n_eval = 64usize;
    let px = model.spec.input.iter().product::<usize>();
    let mut images = vec![0i8; n_eval * px];
    let mut host_preds = Vec::new();
    for j in 0..n_eval {
        let qi = quantize_input(&qm, &model.test.images[j]);
        images[j * px..(j + 1) * px].copy_from_slice(&qi.data);
        host_preds.push(mpnn::models::infer::argmax_i32(&qforward(&qm, &qi)) as i32);
    }
    let stem = format!("{name}_qfwd_b64");
    let have_artifacts = opts.artifacts.join(format!("{stem}.hlo.txt")).exists();
    if have_artifacts {
        // Any PJRT failure (no `pjrt` feature, stale/corrupt artifact)
        // skips this path; the host + ISS halves below still run.
        let pjrt = mpnn::runtime::Session::open(&opts.artifacts).and_then(|mut session| {
            let exe = session.load(&stem)?;
            mpnn::runtime::run_qfwd(exe, &qm, &images, n_eval)
        });
        match pjrt {
            Ok(out) => {
                mpnn::ensure!(out.preds == host_preds, "PJRT and host predictions diverge");
                println!(
                    "PJRT(JAX+Pallas) == Rust host reference: {} predictions bit-exact",
                    n_eval
                );
            }
            Err(e) => println!("(PJRT unavailable — {e}; skipping the PJRT path)"),
        }
    } else {
        println!("(artifacts missing — skipping the PJRT path)");
    }
    let correct = host_preds
        .iter()
        .zip(&model.test.labels)
        .filter(|(&p, &l)| p as usize == l)
        .count();
    println!("quantized accuracy: {:.1}% over {} images", 100.0 * correct as f32 / n_eval as f32, n_eval);

    // --- path 3: the cycle-accurate core --------------------------------
    let input = quantize_input(&qm, &model.test.images[0]);
    let want = qforward(&qm, &input);
    let ext = run_model(&qm, &input, &modes_for(&qm), MacUnitConfig::full())?;
    mpnn::ensure!(ext.logits == want, "ISS logits diverge from host reference");
    let base = run_model(&qm, &input, &baseline_modes(&qm), MacUnitConfig::full())?;
    mpnn::ensure!(base.logits == want, "baseline ISS logits diverge");
    println!("RISC-V ISS (nn_mac kernels) == host reference: logits bit-exact");
    let speedup = base.total_cycles() as f64 / ext.total_cycles() as f64;
    println!(
        "cycles: baseline {} → extended {}  ({speedup:.1}x speedup, {:.0}% fewer memory accesses)",
        base.total_cycles(),
        ext.total_cycles(),
        100.0 * (1.0 - ext.total_accesses() as f64 / base.total_accesses() as f64)
    );

    // --- Table-4-style energy report -------------------------------------
    let macs = analysis.total_macs;
    let rb = ASIC_BASELINE.evaluate(macs, base.total_cycles());
    let rm = ASIC_MODIFIED.evaluate(macs, ext.total_cycles());
    println!(
        "ASIC (ASAP7): {:.1} → {:.1} GOP/s/W  ({:.1}x energy-efficiency gain)",
        rb.gops_per_w,
        rm.gops_per_w,
        rm.gops_per_w / rb.gops_per_w
    );
    println!("full_pipeline OK");
    Ok(())
}
