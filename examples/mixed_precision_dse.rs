//! Mixed-precision DSE on LeNet5: enumerate per-layer bit-width
//! configurations, evaluate accuracy + cycles through the coordinator,
//! print the Pareto front (a small-scale Fig. 6).
//!
//! Run with: `cargo run --release --example mixed_precision_dse`

use mpnn::dse::pareto::pareto_front;
use mpnn::dse::{default_pinned, enumerate};
use mpnn::exp::ExpOpts;

fn main() -> mpnn::Result<()> {
    let opts = ExpOpts { budget: 81, eval_n: 64, ..Default::default() };
    let coordinator = opts.coordinator("lenet5")?;
    let n = mpnn::models::analyze(&coordinator.model.spec).layers.len();
    let configs = enumerate(n, &default_pinned(), opts.budget, 1);
    println!(
        "sweeping {} configurations of lenet5 ({} layers, first pinned to 8-bit)",
        configs.len(),
        n
    );
    let points = coordinator.run_sweep(&configs, opts.eval_n)?;
    let front = pareto_front(&points, |p| p.cycles);
    println!("float accuracy: {:.1}%", coordinator.model.float_acc * 100.0);
    println!("{:>8} {:>10} {:>12} {:>8}  bits", "acc(%)", "cycles", "mac-instrs", "speedup");
    let base = coordinator.cycle_model.baseline_total().cycles;
    for &i in &front {
        let p = &points[i];
        let bits: Vec<String> = p.config.iter().map(|b| b.to_string()).collect();
        println!(
            "{:>8.1} {:>10} {:>12} {:>7.1}x  [{}]",
            p.accuracy * 100.0,
            p.cycles,
            p.mac_instructions,
            base as f64 / p.cycles as f64,
            bits.join(",")
        );
    }
    println!(
        "evaluations: {} (cache hits {})",
        coordinator.metrics.acc_evals.load(std::sync::atomic::Ordering::Relaxed),
        coordinator.metrics.cache_hits.load(std::sync::atomic::Ordering::Relaxed)
    );
    Ok(())
}
